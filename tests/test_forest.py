"""Multi-prefix forest decoding + continuous-batching serve loop.

Covers the tentpole acceptance criteria:
  * grouped caches (bf16 + int8): write_context admission, slot
    assignment/reuse, layout parity, spec surfaces;
  * G > 1 end-to-end: each group's greedy tokens match a per-group
    single-prefix ServeEngine.generate run — bf16 AND int8, einsum AND
    grouped-kernel decode;
  * the decode dispatch compiles ONCE across admit/retire events;
  * continuous-batching edge cases: EOS retirement inside the scan,
    EOS-at-step-0, admit-into-retired-slot reuse;
  * structural no-HBM-spill for the grouped bf16 kernel (the q8 twin is in
    tests/test_fused_q8.py) and grouped sharding specs on an SPMD mesh;
  * per-group IO accounting (core.io_model.forest_decode_io_bytes).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_no_hbm_spill, make_decode_case
from repro.configs import ForestConfig, ServeConfig, get_config, reduced_config
from repro.core.kv_cache import GroupedBifurcatedCache
from repro.core.policy import BifurcationPolicy
from repro.core.quantized import GroupedQuantBifurcatedCache
from repro.models import get_model
from repro.runtime.serve import ForestServeEngine, ServeEngine

pytestmark = pytest.mark.slow  # CI runs the slow tier in its own step

CFG = reduced_config(get_config("internlm2-1.8b"))
MODEL = get_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.RandomState(0)
CTX_A = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 24)))
CTX_B = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 17)))
CTX_C = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 9)))


def _forest(n_groups=2, slots=5, cache_dtype="bfloat16", use_kernel=False,
            **kw):
    fcfg = ForestConfig(n_groups=n_groups, slots=slots, ctx_capacity=32,
                        decode_capacity=16, temperature=0.0,
                        cache_dtype=cache_dtype, use_kernel=use_kernel, **kw)
    return ForestServeEngine(MODEL, CFG, fcfg)


def _single(ctx, batch, cache_dtype="bfloat16", use_kernel=False, n_steps=8):
    scfg = ServeConfig(batch=batch, decode_capacity=16, temperature=0.0,
                       top_p=1.0, bifurcated=True, use_kernel=use_kernel,
                       cache_dtype=cache_dtype)
    pol = BifurcationPolicy(enabled=True, min_io_saving_bytes=0, min_batch=1)
    eng = ServeEngine(MODEL, CFG, scfg, policy=pol)
    return eng.generate(PARAMS, ctx, n_steps=n_steps,
                        key=jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Grouped caches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["gmk", "mgk"])
def test_grouped_cache_write_context_and_lens(layout):
    cache = GroupedBifurcatedCache.init(2, 3, 4, 32, 8, 2, 16,
                                        ctx_layout=layout)
    k = jnp.ones((2, 20, 2, 16), jnp.float32)
    cache = cache.write_context(k, k * 2, 1)
    assert int(cache.ctx_lens[1]) == 20 and int(cache.ctx_lens[0]) == 0
    seg = cache.k_ctx[:, 1]
    live = seg[:, :, :20] if layout == "gmk" else seg[:, :20]
    dead = seg[:, :, 20:] if layout == "gmk" else seg[:, 20:]
    assert float(jnp.min(jnp.abs(live))) > 0          # segment written
    assert float(jnp.max(jnp.abs(dead))) == 0         # capacity tail zero
    assert float(jnp.max(jnp.abs(cache.k_ctx[:, 0]))) == 0  # others intact


def test_grouped_cache_assign_slots_wipes_stale_decode_arm():
    cache = GroupedBifurcatedCache.init(1, 2, 4, 16, 8, 2, 16)
    cache = dataclasses.replace(
        cache, k_dec=jnp.ones_like(cache.k_dec),
        dec_lens=jnp.full((4,), 5, jnp.int32),
        group_ids=jnp.asarray([0, 0, 1, 1], jnp.int32))
    mask = jnp.asarray([False, True, True, False])
    cache = cache.assign_slots(mask, 1)
    np.testing.assert_array_equal(np.asarray(cache.group_ids), [0, 1, 1, 1])
    np.testing.assert_array_equal(np.asarray(cache.dec_lens), [5, 0, 0, 5])
    assert float(jnp.max(jnp.abs(cache.k_dec[:, 1]))) == 0   # wiped
    assert float(jnp.min(jnp.abs(cache.k_dec[:, 0]))) == 1   # kept


@pytest.mark.parametrize("fam", [GroupedBifurcatedCache,
                                 GroupedQuantBifurcatedCache])
def test_grouped_cache_spec_matches_init(fam):
    spec = fam.spec(2, 3, 4, 32, 8, 2, 16)
    real = fam.init(2, 3, 4, 32, 8, 2, 16)
    assert jax.tree.structure(spec) == jax.tree.structure(real)
    for s, r in zip(jax.tree.leaves(spec), jax.tree.leaves(real)):
        assert s.shape == r.shape and s.dtype == r.dtype
    assert spec.n_groups == 3 and spec.context_capacity == 32
    assert spec.n_slots == 4 and spec.decode_capacity == 8


def test_grouped_quant_cache_quantizes_at_admission():
    cache = GroupedQuantBifurcatedCache.init(2, 2, 4, 32, 8, 2, 16)
    rng = np.random.RandomState(3)
    k = jnp.asarray(rng.randn(2, 20, 2, 16), jnp.float32)
    cache = cache.write_context(k, k, 0)
    assert cache.k_ctx.dtype == jnp.int8
    assert int(cache.ctx_lens[0]) == 20
    # k scales carry the logit fold: smaller than the raw v scales
    ks = np.asarray(cache.k_scale[:, 0, :, :20])
    vs = np.asarray(cache.v_scale[:, 0, :, :20])
    assert ks.min() > 0 and np.all(ks < vs)
    np.testing.assert_allclose(ks * 16**0.5, vs, rtol=1e-5)


# ---------------------------------------------------------------------------
# Structural + sharding
# ---------------------------------------------------------------------------

def test_grouped_bf16_kernel_no_hbm_spill():
    """The grouped (forest) bf16 kernel keeps the fused-kernel guarantee:
    ONE pallas_call, one normalized bf16 output, no fp32 partials."""
    from repro.kernels.ops import grouped_bifurcated_decode_attention

    case = make_decode_case(2, 2, 64, 8, g=2, hd=32, dtype=jnp.bfloat16,
                            seed=1, full_mask=True)
    gids = jnp.zeros((2,), jnp.int32)
    clens = jnp.asarray([64], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: grouped_bifurcated_decode_attention(
            *a, interpret=True, ctx_layout="mgk")
    )(case["q"], case["kc"][None], case["vc"][None], gids, clens,
      case["kd"], case["vd"], case["mask"]).jaxpr
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16)


@pytest.mark.parametrize("ctx_quant", ["none", "int8"])
@pytest.mark.parametrize("layout", ["gmk", "mgk"])
def test_forest_cache_pspec_tree_layout_aware(ctx_quant, layout):
    from repro.core.quantized import forest_cache_family
    from repro.launch.steps import cache_pspec_tree

    fam = forest_cache_family(ctx_quant)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = fam.spec(2, 2, 4, 64, 8, 2, 16, ctx_layout=layout)
    ps = cache_pspec_tree(mesh, spec)
    ctx_dim = 3 if layout == "gmk" else 2
    assert ps.k_ctx[ctx_dim] == "model"          # ctx seq dim sharded
    assert all(ax is None for i, ax in enumerate(ps.k_ctx) if i != ctx_dim)
    assert ps.k_dec[2] == "model"
    if ctx_quant == "int8":
        assert ps.k_scale[ctx_dim] == "model"    # scales follow the values
    assert ps.ctx_lens == jax.sharding.PartitionSpec()


def test_forest_decode_spmd_compiles_on_8_devices():
    """Grouped decode_step lowers + compiles under an 8-device (2, 4) SPMD
    mesh with the forest cache sharded by launch.steps.cache_pspec_tree
    (context sequence dim over "model"), bf16 AND int8 families."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = """
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.launch import specs as S, steps as ST
        from repro.models import get_model

        cfg = reduced_config(get_config("internlm2-1.8b"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        with mesh:
            model = get_model(cfg)
            params = S.param_specs(model)
            rules = ST.MeshRules.serving()
            psh = ST.to_named(mesh, ST.param_pspec_tree(params, rules))
            for quant in ("none", "int8"):
                io = S.forest_decode_cache_specs(
                    cfg, model, slots=4, n_groups=2, ctx_capacity=64,
                    dec_capacity=8, ctx_quant=quant)
                csh = ST.to_named(mesh, ST.cache_pspec_tree(mesh, io["cache"]))
                tsh = ST.to_named(mesh, ST.batch_pspec_tree(
                    mesh, {"tokens": io["tokens"]}))["tokens"]
                compiled = jax.jit(
                    lambda p, c, t: model.decode_step(p, c, t, None),
                    in_shardings=(psh, csh, tsh), donate_argnums=(1,),
                ).lower(params, io["cache"], io["tokens"]).compile()
                out[quant] = int(
                    compiled.memory_analysis().argument_size_in_bytes)
        print(json.dumps(out))
    """
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["none"] > 0 and out["int8"] > 0
    assert out["int8"] < out["none"]     # int8 segments shrink the args


# ---------------------------------------------------------------------------
# End-to-end acceptance: G > 1 forest == per-group single-prefix engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cache_dtype,use_kernel", [
    ("bfloat16", False), ("bfloat16", True),
    ("int8", False), ("int8", True),
])
def test_forest_matches_per_group_single_prefix(cache_dtype, use_kernel):
    """ISSUE acceptance: for G > 1 each group's greedy tokens are IDENTICAL
    to a per-group single-prefix ServeEngine.generate run (bf16 and int8,
    einsum and grouped-kernel decode paths)."""
    eng = _forest(cache_dtype=cache_dtype, use_kernel=use_kernel)
    st = eng.init_state()
    st, slots_a = eng.admit(PARAMS, st, CTX_A, 3)
    st, slots_b = eng.admit(PARAMS, st, CTX_B, 2)
    st = eng.step_chunk(PARAMS, st, 7)
    r_a = _single(CTX_A, 3, cache_dtype, use_kernel)
    r_b = _single(CTX_B, 2, cache_dtype, use_kernel)
    np.testing.assert_array_equal(
        np.stack([eng.outputs[s] for s in slots_a]), np.asarray(r_a.tokens))
    np.testing.assert_array_equal(
        np.stack([eng.outputs[s] for s in slots_b]), np.asarray(r_b.tokens))


def test_forest_decode_dispatch_compiles_once_across_admit_retire():
    """ISSUE acceptance: admission state is data, not shape — the jitted
    decode chunk compiles exactly once across admit / step / retire /
    re-admit cycles."""
    eng = _forest(n_groups=2, slots=4)
    st = eng.init_state()
    st, slots_a = eng.admit(PARAMS, st, CTX_A, 2)
    st = eng.step_chunk(PARAMS, st, 4)
    st, slots_b = eng.admit(PARAMS, st, CTX_B, 2)
    st = eng.step_chunk(PARAMS, st, 4)
    # force-retire group A's slots, free its segment, admit a new request
    # into the SAME slots + segment, keep decoding
    st = dataclasses.replace(
        st, active=st.active & ~jnp.isin(jnp.arange(4),
                                         jnp.asarray(slots_a)))
    assert eng.retire_groups(st) != []
    st, slots_c = eng.admit(PARAMS, st, CTX_C, 2)
    assert set(slots_c) == set(slots_a)          # retired slots reused
    st = eng.step_chunk(PARAMS, st, 4)
    assert eng.decode_dispatches == 3
    assert eng._chunk._cache_size() == 1         # ONE compile for them all


def test_forest_readmitted_slots_decode_correctly():
    """Admit-into-retired-slot reuse: after a group retires, a new request
    admitted into its slots produces the same tokens as a fresh engine
    (stale decode KVs are wiped by assign_slots)."""
    eng = _forest(n_groups=2, slots=4)
    st = eng.init_state()
    st, slots_a = eng.admit(PARAMS, st, CTX_A, 2)
    st = eng.step_chunk(PARAMS, st, 5)
    st = dataclasses.replace(
        st, active=st.active & ~jnp.isin(jnp.arange(4),
                                         jnp.asarray(slots_a)))
    eng.retire_groups(st)
    st, slots_c = eng.admit(PARAMS, st, CTX_C, 2)
    st = eng.step_chunk(PARAMS, st, 7)
    ref = _single(CTX_C, 2)
    np.testing.assert_array_equal(
        np.stack([eng.outputs[s] for s in slots_c]), np.asarray(ref.tokens))


def test_forest_eos_retires_slot_inside_scan():
    """EOS retirement lives INSIDE the jitted scan carry: a slot that
    samples eos_token stops emitting (pad from then on), its step counter
    freezes, and other slots are unaffected."""
    eng0 = _forest()          # find the greedy token stream first
    st0 = eng0.init_state()
    st0, slots0 = eng0.admit(PARAMS, st0, CTX_A, 2)
    st0 = eng0.step_chunk(PARAMS, st0, 6)
    stream = eng0.outputs[slots0[0]]
    eos = stream[3]           # retire after 3 post-prefill steps
    k_eos = stream.index(eos)  # first emission of that token (may be < 3)

    eng = _forest(eos_token=int(eos), pad_token=-7)
    st = eng.init_state()
    st, slots = eng.admit(PARAMS, st, CTX_A, 2)
    st = eng.step_chunk(PARAMS, st, 6)
    out = eng.outputs[slots[0]]
    assert out == stream[:k_eos + 1]             # emitted up to & incl. EOS
    assert not bool(st.active[slots[0]])         # retired in-scan
    assert int(st.steps[slots[0]]) == k_eos      # step counter frozen
    # retirement happened mid-chunk, with shapes unchanged and one compile
    assert eng._chunk._cache_size() == 1


def test_forest_eos_at_step_0_retires_before_decode():
    """A first token (sampled from the prefill logits) equal to eos_token
    retires the slot before it ever enters the decode loop."""
    probe = _forest()
    st = probe.init_state()
    st, slots = probe.admit(PARAMS, st, CTX_A, 2)
    first = probe.outputs[slots[0]][0]

    eng = _forest(eos_token=int(first))
    st = eng.init_state()
    st, slots = eng.admit(PARAMS, st, CTX_A, 2)
    assert not bool(st.active[slots[0]])         # EOS at step 0
    assert eng.outputs[slots[0]] == [first]
    st = eng.step_chunk(PARAMS, st, 4)
    assert eng.outputs[slots[0]] == [first]      # nothing further emitted
    # the whole group retires once every slot has hit EOS
    if not any(bool(st.active[s]) for s in slots):
        assert eng.retire_groups(st) != []


def test_forest_eos_slot_of_live_group_not_reused_until_retire():
    """An EOS'd slot whose group is still live keeps its finished output
    readable: free_slots excludes it (admitting into it would clobber the
    host-side result lists) until retire_groups frees the whole group."""
    probe = _forest()
    st = probe.init_state()
    st, slots = probe.admit(PARAMS, st, CTX_A, 2)
    first = probe.outputs[slots[0]][0]

    eng = _forest(n_groups=3, eos_token=int(first))
    st = eng.init_state()
    st, slots = eng.admit(PARAMS, st, CTX_A, 2)
    # greedy sampling from the shared prefill logits: BOTH fanned-out slots
    # sample `first` and EOS at step 0 — the group is fully inactive but
    # NOT yet retired, so its finished outputs must stay readable
    assert not any(bool(st.active[s]) for s in slots)
    free = eng.free_slots(st)
    assert all(s not in free for s in slots)      # NOT reusable yet
    st, slots_b = eng.admit(PARAMS, st, CTX_B, 2)
    assert not set(slots) & set(slots_b)          # admit used fresh slots
    assert eng.outputs[slots[0]] == [first]       # finished output intact
    # after the whole group retires, the slot becomes reusable
    st = dataclasses.replace(
        st, active=st.active & ~jnp.isin(jnp.arange(eng.fcfg.slots),
                                         jnp.asarray(slots)))
    eng.retire_groups(st)
    assert slots[0] in eng.free_slots(st)


def test_forest_step_chunk_guards_decode_capacity():
    """Decoding past a live slot's decode capacity would silently clamp
    the KV write at the last cache slot (corrupting that slot's decode
    arm) — step_chunk refuses up front instead."""
    eng = _forest()                     # decode_capacity=16
    st = eng.init_state()
    st, slots = eng.admit(PARAMS, st, CTX_A, 2)
    st = eng.step_chunk(PARAMS, st, 10)
    with pytest.raises(RuntimeError, match="decode_capacity"):
        eng.step_chunk(PARAMS, st, 7)   # deepest live slot at 10: 10+7 > 16
    st = eng.step_chunk(PARAMS, st, 6)  # exactly at capacity is fine
    assert all(len(eng.outputs[s]) == 17 for s in slots)
    # retired slots don't count: deactivate, then long chunks are legal
    st = dataclasses.replace(st, active=jnp.zeros_like(st.active))
    st = eng.step_chunk(PARAMS, st, 7)


def test_forest_admit_exhaustion_raises():
    eng = _forest(n_groups=1, slots=2)
    st = eng.init_state()
    st, _ = eng.admit(PARAMS, st, CTX_A, 2)
    with pytest.raises(RuntimeError):
        eng.admit(PARAMS, st, CTX_B, 1)          # no free segment
    eng2 = _forest(n_groups=2, slots=2)
    st2 = eng2.init_state()
    st2, _ = eng2.admit(PARAMS, st2, CTX_A, 2)
    with pytest.raises(RuntimeError):
        eng2.admit(PARAMS, st2, CTX_B, 1)        # no free slot


# ---------------------------------------------------------------------------
# Per-group IO accounting
# ---------------------------------------------------------------------------

def test_forest_io_bytes_per_group_accounting():
    from repro.core.io_model import (
        decode_impl_io_bytes,
        forest_decode_io_bytes,
    )

    io = forest_decode_io_bytes(group_sizes=[16, 4], ctx_lens=[4096, 512],
                                c_d=32, g=8, hd=128)
    assert len(io["per_group"]) == 2
    assert io["per_group"][0] > io["per_group"][1]   # longer + wider group
    assert io["total"] == sum(io["per_group"]) + (16 + 4) * 8 * 128 * 2 * 2
    assert io["io_saving"] > 5                       # mixed-batch saving
    # G=1 full population reduces exactly to the single-prefix fused model
    one = forest_decode_io_bytes(group_sizes=[16], ctx_lens=[4096],
                                 c_d=32, g=8, hd=128)
    assert one["total"] == decode_impl_io_bytes(
        b=16, p=1, n=1, m_c=4096, c_d=32, g=8, hd=128, impl="fused")
    # q8 segments halve the dominant (context) term
    q8 = forest_decode_io_bytes(group_sizes=[16, 4], ctx_lens=[4096, 512],
                                c_d=32, g=8, hd=128, impl="grouped_q8")
    assert q8["total"] < io["total"]
    assert q8["io_saving"] > io["io_saving"]
    # padded-envelope accounting (what the CURRENT kernel DMAs: every
    # segment at full capacity, freed segments included) costs more than
    # the live-length model and coincides with it when segments are full
    env = forest_decode_io_bytes(group_sizes=[16, 4, 0],
                                 ctx_lens=[4096, 512, 0],
                                 c_d=32, g=8, hd=128, ctx_capacity=4096)
    assert env["total"] > io["total"]
    assert env["io_saving"] < io["io_saving"]
    full = forest_decode_io_bytes(group_sizes=[16, 4], ctx_lens=[4096, 4096],
                                  c_d=32, g=8, hd=128)
    assert full["total"] == forest_decode_io_bytes(
        group_sizes=[16, 4], ctx_lens=[4096, 4096], c_d=32, g=8, hd=128,
        ctx_capacity=4096)["total"]
