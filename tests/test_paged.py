"""Paged KV storage substrate (core/paged.py + the paged page-walk kernels).

Covers the tentpole acceptance criteria beyond the differential-harness
cross-checks (which live in tests/test_differential.py):

  * the page-pool stores (bf16 + int8): write/clear roundtrips through
    shuffled pool pages, dense materialization, allocator bookkeeping;
  * paged kernels BIT-IDENTICAL to the dense tree kernels on the same
    logical contents (ragged lengths, permuted pages, FREE nodes — both
    dtypes) and within oracle tolerance of the concatenated-context
    reference;
  * STRUCTURAL DMA elision: the live-page list streams exactly
    sum(ceil(len/page_m)) context blocks — FREE segments and dead tails
    contribute none, and clearing a segment shrinks the stream (the dense
    grid streams the full capacity envelope regardless);
  * the fused no-HBM-spill contract (one pallas_call, output-only) and
    the q8 no-dequant guarantee hold for the paged kernels;
  * paged cache families: spec/init parity, slot wipes, decode-step
    dispatch (einsum escape hatch == kernel), sharding pspecs;
  * engines under ctx_store="paged": greedy tokens identical to the dense
    engines, admission REJECTION (capacity + pool exhaustion), page
    refcounts across trie reuse/retire, decode compiles ONCE across
    admit/retire/readmit, and release_retired structurally shrinking the
    page stream;
  * core.io_model.paged_decode_io_bytes: page-rounded live bytes, free
    nodes at zero, the dense envelope recovered.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_no_hbm_spill, build_page_pool
from repro.core.paged import (
    PageAllocator,
    PagedBifurcatedCache,
    PagedGroupedBifurcatedCache,
    PagedKVStore,
    PagedPrefixTreeCache,
    QuantPagedKVStore,
    gather_pages,
    pages_needed,
)
from repro.core.quantized import quantize_ctx
from repro.kernels.ops import (
    live_page_list,
    paged_bifurcated_decode_attention,
    paged_bifurcated_decode_attention_q8,
    tree_bifurcated_decode_attention,
    tree_bifurcated_decode_attention_q8,
)

G, HD, PM = 2, 32, 64


# ---------------------------------------------------------------------------
# Case builder: one ragged trie in BOTH dense-segment and page-pool form
# ---------------------------------------------------------------------------

def make_paged_trie(node_lens, paths_cols, *, b=None, c_d=8, page_m=PM,
                    node_capacity=None, seed=0, dtype=jnp.bfloat16,
                    extra_pages=2):
    """Build one decode problem over a ragged trie twice: dense "gmk" node
    segments (zero-padded to capacity) and a page pool holding the SAME
    logical contents on shuffled pool pages (conftest.build_page_pool)."""
    rng = np.random.RandomState(seed)
    n_nodes = len(node_lens)
    node_capacity = node_capacity or max(
        pages_needed(m, page_m) for m in node_lens) * page_m
    cap = pages_needed(node_capacity, page_m) * page_m
    b = b or len(paths_cols)

    kc = np.zeros((n_nodes, G, cap, HD), np.float32)
    vc = np.zeros_like(kc)
    for i, m in enumerate(node_lens):
        kc[i, :, :m] = rng.randn(G, m, HD)
        vc[i, :, :m] = rng.randn(G, m, HD)
    kc, vc = jnp.asarray(kc, dtype), jnp.asarray(vc, dtype)
    # q8 twins: quantize the DENSE segments, then page values + scales
    kq, ks = quantize_ctx(kc, fold_scale=HD**-0.5)
    vq, vs = quantize_ctx(vc)
    (kp, vp, kpq, vpq, ksp, vsp), tables = build_page_pool(
        [kc, vc, kq, vq, ks, vs], node_lens, page_m,
        perm_seed=seed, extra_pages=extra_pages)

    case = {
        "kc": kc, "vc": vc, "kp": kp, "vp": vp,
        "kq": kq, "vq": vq, "ks": ks, "vs": vs,
        "kpq": kpq, "vpq": vpq, "ksp": ksp, "vsp": vsp,
        "tables": tables,
        "nlens": jnp.asarray(node_lens, jnp.int32),
        "q": jnp.asarray(rng.randn(b, G, 1, 1, HD), dtype),
        "kd": jnp.asarray(rng.randn(b, c_d, G, HD), dtype),
        "vd": jnp.asarray(rng.randn(b, c_d, G, HD), dtype),
        "mask": jnp.arange(c_d)[None, :] < jnp.asarray(
            rng.randint(1, c_d + 1, size=(b,)))[:, None],
        "page_m": page_m, "cap": cap,
    }
    depth = max(len(p) for p in paths_cols)
    table = np.full((depth, b), -1, np.int64)
    for s, pth in enumerate(paths_cols):
        table[:len(pth), s] = pth
    case["paths"] = jnp.asarray(table, jnp.int32)
    return case


RAGGED = dict(node_lens=[160, 37, 96, 0],          # node 3 FREE
              paths_cols=[(0,), (0, 1), (0, 2), (1,), (0, 1)])


# ---------------------------------------------------------------------------
# Stores + allocator
# ---------------------------------------------------------------------------

def test_store_write_roundtrip_shuffled_pages():
    st = PagedKVStore.init(2, 3, 4, 10, G, HD, page_m=8)
    rng = np.random.RandomState(0)
    k = jnp.asarray(rng.randn(2, 19, G, HD), jnp.bfloat16)
    v = jnp.asarray(rng.randn(2, 19, G, HD), jnp.bfloat16)
    st = st.write_segment(k, v, 1, [7, 2, 9])      # 19 tokens -> 3 pages
    kd, vd = st.dense_ctx()
    ref = k.transpose(0, 2, 1, 3)                  # (L, g, m, hd)
    assert bool(jnp.all(kd[:, 1, :, :19] == ref))
    assert float(jnp.max(jnp.abs(kd[:, 1, :, 19:]))) == 0   # page tail zero
    assert float(jnp.max(jnp.abs(kd[:, 0]))) == 0           # others intact
    assert int(st.seg_lens[1]) == 19
    np.testing.assert_array_equal(np.asarray(st.page_tables[1]),
                                  [7, 2, 9, -1])
    st = st.clear_segment(1)
    assert int(st.seg_lens[1]) == 0
    assert int(jnp.max(st.page_tables[1])) == -1


def test_quant_store_roundtrip_and_scale_fold():
    st = QuantPagedKVStore.init(1, 2, 4, 8, G, HD, page_m=8)
    rng = np.random.RandomState(1)
    k = jnp.asarray(rng.randn(1, 21, G, HD), jnp.float32)
    v = jnp.asarray(rng.randn(1, 21, G, HD), jnp.float32)
    st = st.write_segment(k, v, 0, [5, 0, 3])
    kq, vq, ks, vs = st.dense_ctx()
    ref = k.transpose(0, 2, 1, 3)
    # k scales carry hd**-0.5 pre-folded (the dense families' contract)
    deq = kq[:, 0, :, :21].astype(jnp.float32) * ks[:, 0, :, :21, None] \
        * (HD**0.5)
    assert float(jnp.max(jnp.abs(deq - ref))) < 0.05
    deqv = vq[:, 0, :, :21].astype(jnp.float32) * vs[:, 0, :, :21, None]
    assert float(jnp.max(jnp.abs(deqv - v.transpose(0, 2, 1, 3)))) < 0.05


def test_store_rejects_overflow_and_bad_page_count():
    st = PagedKVStore.init(1, 2, 2, 8, G, HD, page_m=8)   # cap 16 tokens
    k = jnp.ones((1, 17, G, HD), jnp.bfloat16)
    with pytest.raises(ValueError, match="segment capacity"):
        st.write_segment(k, k, 0, [0, 1, 2])
    k = jnp.ones((1, 12, G, HD), jnp.bfloat16)
    with pytest.raises(ValueError, match="page ids"):
        st.write_segment(k, k, 0, [0])                    # needs 2 pages


def test_page_allocator_refcounts_and_exhaustion():
    al = PageAllocator(4)
    a = al.alloc(3)
    assert al.free_count() == 1
    al.share(a[:1])
    assert al.release(a[:1]) == []          # still referenced
    assert al.release(a) == a               # refcounts hit zero in order
    assert al.free_count() == 4
    al.alloc(4)
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc(1)


# ---------------------------------------------------------------------------
# Structural DMA elision: the live-page list IS the context stream
# ---------------------------------------------------------------------------

def test_live_page_list_streams_only_live_pages():
    """The paged grid's context stream is the prefix-counted page list:
    exactly sum(ceil(len/page_m)) blocks — FREE segments and dead capacity
    contribute ZERO entries, and the padded tail repeats the last live
    page (same block index => the revisiting rule elides its DMA). The
    dense tree grid streams n_nodes * (capacity/block) blocks regardless."""
    case = make_paged_trie(**RAGGED)
    ids, segs, n_live, bias = live_page_list(case["tables"], case["nlens"],
                                             case["page_m"])
    expect = sum(pages_needed(m, case["page_m"]) for m in RAGGED["node_lens"])
    assert int(n_live[0]) == expect
    # context blocks streamed = distinct consecutive block indices
    ids_np = np.asarray(ids)
    streamed = 1 + int(np.sum(ids_np[1:] != ids_np[:-1]))
    assert streamed == expect
    # dense envelope for the same trie: every node, every capacity block
    dense_blocks = len(RAGGED["node_lens"]) * (case["cap"] // case["page_m"])
    assert streamed < dense_blocks
    # (segment, page) stream order — the dense kernels' (node, block) order
    np.testing.assert_array_equal(np.asarray(segs)[:expect],
                                  [0, 0, 0, 1, 2, 2])
    # clearing a segment structurally shrinks the stream
    tables2 = case["tables"].at[0].set(-1)
    nlens2 = case["nlens"].at[0].set(0)
    _, _, n_live2, _ = live_page_list(tables2, nlens2, case["page_m"])
    assert int(n_live2[0]) == expect - pages_needed(160, case["page_m"])


def test_live_page_list_bias_masks_ragged_tails():
    case = make_paged_trie(**RAGGED)
    ids, segs, n_live, bias = live_page_list(case["tables"], case["nlens"],
                                             case["page_m"])
    bias = np.asarray(bias)
    # node 1 (len 37) occupies one 64-token page: cols 37.. masked
    entry = int(np.where(np.asarray(segs)[:int(n_live[0])] == 1)[0][0])
    assert (bias[entry, :37] == 0).all() and (bias[entry, 37:] < -1e29).all()


# ---------------------------------------------------------------------------
# Kernel exactness: bit-identical to the dense tree kernels
# ---------------------------------------------------------------------------

def test_paged_kernel_bit_identical_to_dense_tree():
    """ISSUE acceptance: on the same logical contents (ragged lengths,
    permuted pool pages, a FREE node) the paged kernel's output is
    BIT-identical to the dense tree kernel at block_m == page_m — the
    skipped blocks' contributions are exact zeros (or pre-first-column
    state wiped by the corr == 0 rescale), both dtypes."""
    case = make_paged_trie(**RAGGED)
    out_d = tree_bifurcated_decode_attention(
        case["q"], case["kc"], case["vc"], case["paths"], case["nlens"],
        case["kd"], case["vd"], case["mask"],
        block_m=case["page_m"], interpret=True, ctx_layout="gmk")
    out_p = paged_bifurcated_decode_attention(
        case["q"], case["kp"], case["vp"], case["tables"], case["nlens"],
        case["paths"], case["kd"], case["vd"], case["mask"], interpret=True)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_d))

    out_dq = tree_bifurcated_decode_attention_q8(
        case["q"], case["kq"], case["vq"], case["ks"], case["vs"],
        case["paths"], case["nlens"], case["kd"], case["vd"], case["mask"],
        block_m=case["page_m"], interpret=True, ctx_layout="gmk")
    out_pq = paged_bifurcated_decode_attention_q8(
        case["q"], case["kpq"], case["vpq"], case["ksp"], case["vsp"],
        case["tables"], case["nlens"], case["paths"],
        case["kd"], case["vd"], case["mask"], interpret=True)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_dq))


def test_paged_kernel_vs_concat_oracle():
    """Multi-level correctness in f32: each slot's paged output equals
    standard attention over [its path's concatenated live context ⊕ its
    decode slots]."""
    from repro.core.attention import decode_attention

    case = make_paged_trie(**RAGGED, dtype=jnp.float32, seed=3)
    out = paged_bifurcated_decode_attention(
        case["q"], case["kp"], case["vp"], case["tables"], case["nlens"],
        case["paths"], case["kd"], case["vd"], case["mask"], interpret=True)
    paths = np.asarray(case["paths"])
    lens = np.asarray(case["nlens"])
    kc = np.asarray(case["kc"], np.float32)   # (N, g, cap, hd)
    vc = np.asarray(case["vc"], np.float32)
    for s in range(paths.shape[1]):
        pth = [int(n) for n in paths[:, s] if n >= 0]
        ks = np.concatenate([kc[n, :, :lens[n]] for n in pth], axis=1)
        vs = np.concatenate([vc[n, :, :lens[n]] for n in pth], axis=1)
        m = ks.shape[1]
        K = jnp.asarray(ks.transpose(1, 0, 2))[None]   # (1, m, g, hd)
        V = jnp.asarray(vs.transpose(1, 0, 2))[None]
        K = jnp.concatenate([K, case["kd"][s:s + 1]], axis=1)
        V = jnp.concatenate([V, case["vd"][s:s + 1]], axis=1)
        valid = jnp.concatenate(
            [jnp.ones((1, m), bool), case["mask"][s:s + 1]], axis=1)
        ref = decode_attention(case["q"][s:s + 1], K, V, valid_mask=valid)
        np.testing.assert_allclose(np.asarray(out[s:s + 1]),
                                   np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_paged_kernel_no_hbm_spill_and_no_dequant():
    """The fused structural contract holds for the paged kernels: ONE
    pallas_call whose only output is the normalized result, and (q8) the
    pool enters exclusively as int8 — no dequantized page buffer in HBM."""
    case = make_paged_trie(**RAGGED)
    jaxpr = jax.make_jaxpr(
        lambda *a: paged_bifurcated_decode_attention(*a, interpret=True))(
        case["q"], case["kp"], case["vp"], case["tables"], case["nlens"],
        case["paths"], case["kd"], case["vd"], case["mask"])
    assert_no_hbm_spill(jaxpr.jaxpr, out_dtype=jnp.bfloat16)
    jaxpr_q8 = jax.make_jaxpr(
        lambda *a: paged_bifurcated_decode_attention_q8(*a, interpret=True))(
        case["q"], case["kpq"], case["vpq"], case["ksp"], case["vsp"],
        case["tables"], case["nlens"], case["paths"],
        case["kd"], case["vd"], case["mask"])
    assert_no_hbm_spill(jaxpr_q8.jaxpr, out_dtype=jnp.bfloat16, hd=HD,
                        q8=True)


# ---------------------------------------------------------------------------
# Paged cache families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", ["none", "int8"])
@pytest.mark.parametrize("fam,args", [
    (PagedPrefixTreeCache, (2, 3, 2, 4, 96, 8, G, HD)),
    (PagedGroupedBifurcatedCache, (2, 3, 4, 96, 8, G, HD)),
])
def test_paged_cache_spec_matches_init(fam, args, quant):
    spec = fam.spec(*args, page_m=32, ctx_quant=quant)
    real = fam.init(*args, page_m=32, ctx_quant=quant)
    assert jax.tree.structure(spec) == jax.tree.structure(real)
    for s, r in zip(jax.tree.leaves(spec), jax.tree.leaves(real)):
        assert s.shape == r.shape and s.dtype == r.dtype
    assert spec.decode_capacity == 8 and spec.page_m == 32
    store = spec.store
    assert store.segment_capacity == 96 and store.pages_per_segment == 3
    assert store.num_pages == 9          # full envelope by default


def test_paged_cache_oversubscribed_pool():
    c = PagedPrefixTreeCache.init(1, 8, 2, 4, 256, 8, G, HD,
                                  page_m=64, num_pages=12)
    assert c.store.num_pages == 12       # < 8 * 4 = 32 table envelope
    assert c.node_capacity == 256


def test_paged_assign_paths_wipes_stale_decode_arm():
    c = PagedPrefixTreeCache.init(1, 4, 2, 4, 16, 8, G, HD, page_m=8)
    c = dataclasses.replace(
        c, k_dec=jnp.ones_like(c.k_dec),
        dec_lens=jnp.full((4,), 5, jnp.int32),
        paths=jnp.asarray([[0, 0, 1, 1], [2, -1, 3, -1]], jnp.int32))
    mask = jnp.asarray([False, True, True, False])
    c = c.assign_paths(mask, jnp.asarray([1, 3], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(c.paths), [[0, 1, 1, 1], [2, 3, 3, -1]])
    np.testing.assert_array_equal(np.asarray(c.dec_lens), [5, 0, 0, 5])
    assert float(jnp.max(jnp.abs(c.k_dec[:, 1]))) == 0
    assert float(jnp.min(jnp.abs(c.k_dec[:, 0]))) == 1


def test_single_prefix_cache_adapter_views():
    rng = np.random.RandomState(2)
    k = jnp.asarray(rng.randn(1, 21, G, HD), jnp.bfloat16)
    c = PagedBifurcatedCache.from_prefill(k, k, 3, 8, page_m=8)
    assert int(c.context_len) == 21
    assert c.store.num_pages == 3        # exactly ceil(21/8)
    np.testing.assert_array_equal(np.asarray(c.slot_paths()), [[0, 0, 0]])
    np.testing.assert_array_equal(np.asarray(c.slot_context_lens()),
                                  [21, 21, 21])
    c = c.advance_decode(c.k_dec, c.v_dec, 2)
    np.testing.assert_array_equal(np.asarray(c.slot_dec_lens()), [2, 2, 2])


def test_gather_pages_matches_dense_layout():
    case = make_paged_trie(**RAGGED)
    kd = gather_pages(case["kp"], case["tables"])    # per-layer form
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(case["kc"]))


# ---------------------------------------------------------------------------
# IO model
# ---------------------------------------------------------------------------

def test_paged_decode_io_bytes_page_rounding_and_envelopes():
    from repro.core.io_model import paged_decode_io_bytes

    io = paged_decode_io_bytes(
        node_lens=[160, 37, 96, 0], page_m=64, c_d=8, g=G, hd=HD, b=4,
        node_capacity=192, n_nodes=4)
    per_tok = 2 * G * HD * 2
    assert io["per_node"][0] == 192 * per_tok     # 160 -> 3 pages
    assert io["per_node"][1] == 64 * per_tok      # 37 -> 1 page
    assert io["per_node"][3] == 0                 # FREE node: zero bytes
    fixed = io["total"] - sum(io["per_node"])
    assert io["live_total"] == (160 + 37 + 96) * per_tok + fixed
    assert io["dense_total"] == 4 * 192 * per_tok + fixed
    assert 1.0 <= io["paged_overhead_vs_live"] < 1.35
    assert io["saving_vs_dense"] > 1.5
    io_q8 = paged_decode_io_bytes(
        node_lens=[160, 37, 96, 0], page_m=64, c_d=8, g=G, hd=HD, b=4,
        impl="paged_q8", node_capacity=192, n_nodes=4)
    assert io_q8["total"] < io["total"]           # int8 pages cost less


# ---------------------------------------------------------------------------
# Model-level decode + sharding (slow tier)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    from repro.configs import get_config, reduced_config
    from repro.models import get_model

    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.slow
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_paged_decode_step_kernel_matches_einsum(small_model, quant):
    """Model-level dispatch: the paged kernel path and the dense-
    materializing einsum escape hatch agree on a ragged paged trie."""
    cfg, model, params = small_model
    c = PagedPrefixTreeCache.init(
        cfg.n_layers, 3, 2, 4, 32, 8, cfg.n_kv_heads_padded, cfg.kq_dim,
        page_m=8, ctx_quant=quant)
    rng = np.random.RandomState(0)
    kv = lambda m: (jnp.asarray(
        rng.randn(cfg.n_layers, m, cfg.n_kv_heads_padded, cfg.kq_dim),
        jnp.bfloat16),) * 2
    c = c.write_node(*kv(21), 0, [0, 1, 2])
    c = c.write_node(*kv(9), 2, [5, 3])
    c = c.assign_paths(jnp.asarray([True, True, False, True]),
                       jnp.asarray([0, 2], jnp.int32))
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 1)))
    le, ce = model.decode_step(params, c, toks, None, impl="einsum")
    lk, ck = model.decode_step(params, c, toks, None, impl="kernel")
    le, lk = np.asarray(le, np.float32), np.asarray(lk, np.float32)
    scale = max(float(np.max(np.abs(le))), 1.0)
    assert float(np.max(np.abs(le - lk))) <= 2e-2 * scale
    np.testing.assert_array_equal(np.asarray(ce.dec_lens),
                                  np.asarray(ck.dec_lens))


def test_paged_cache_pspec_pool_head_axis(small_model):
    """launch.steps.cache_pspec_tree shards the page pool's HEAD axis over
    "model" (dim 2 of (L, P, g, pm, hd) — the sequence axis is
    page-chunked), scale pages following identically, with page tables /
    lengths / paths replicated."""
    from repro.launch import specs as S, steps as ST

    cfg, model, _ = small_model
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rep = jax.sharding.PartitionSpec()
    for quant in ("none", "int8"):
        io = S.paged_decode_cache_specs(
            cfg, model, slots=4, n_segments=2, depth=2, node_capacity=64,
            page_m=32, dec_capacity=8, ctx_quant=quant)
        ps = ST.cache_pspec_tree(mesh, io["cache"])
        assert ps.store.k_pages[2] == "model"     # pool head axis sharded
        assert all(ax is None for i, ax in enumerate(ps.store.k_pages)
                   if i != 2)
        assert ps.k_dec[2] == "model"
        if quant == "int8":
            assert ps.store.k_scale_pages[2] == "model"  # scales follow
        assert ps.store.page_tables == rep
        assert ps.store.seg_lens == rep
        assert ps.paths == rep and ps.dec_lens == rep


@pytest.mark.slow
def test_paged_decode_spmd_compiles_on_8_devices():
    """Paged decode_step lowers + compiles under an 8-device (2, 4) SPMD
    mesh with the paged cache sharded by launch.steps.cache_pspec_tree
    (pool head axis over "model"), bf16 AND int8 stores — and the int8
    pool shrinks the argument bytes."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = """
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.launch import specs as S, steps as ST
        from repro.models import get_model

        cfg = reduced_config(get_config("internlm2-1.8b"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        with mesh:
            model = get_model(cfg)
            params = S.param_specs(model)
            rules = ST.MeshRules.serving()
            psh = ST.to_named(mesh, ST.param_pspec_tree(params, rules))
            for quant in ("none", "int8"):
                io = S.paged_decode_cache_specs(
                    cfg, model, slots=4, n_segments=2, depth=2,
                    node_capacity=64, page_m=32, dec_capacity=8,
                    ctx_quant=quant)
                csh = ST.to_named(mesh, ST.cache_pspec_tree(mesh, io["cache"]))
                tsh = ST.to_named(mesh, ST.batch_pspec_tree(
                    mesh, {"tokens": io["tokens"]}))["tokens"]
                compiled = jax.jit(
                    lambda p, c, t: model.decode_step(p, c, t, None),
                    in_shardings=(psh, csh, tsh), donate_argnums=(1,),
                ).lower(params, io["cache"], io["tokens"]).compile()
                out[quant] = int(
                    compiled.memory_analysis().argument_size_in_bytes)
        print(json.dumps(out))
    """
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["none"] > 0 and out["int8"] > 0
    assert out["int8"] < out["none"]     # int8 pool shrinks the args


# ---------------------------------------------------------------------------
# Engines under ctx_store="paged" (slow tier)
# ---------------------------------------------------------------------------

def _engines(small_model):
    from repro.configs import ForestConfig, TreeConfig
    from repro.runtime.serve import ForestServeEngine, TreeServeEngine

    cfg, model, params = small_model

    def forest(ctx_store="dense", **kw):
        base = dict(n_groups=2, slots=5, ctx_capacity=32, decode_capacity=16,
                    temperature=0.0, ctx_store=ctx_store, page_size=8)
        base.update(kw)
        return ForestServeEngine(model, cfg, ForestConfig(**base))

    def tree(ctx_store="dense", **kw):
        base = dict(n_nodes=4, depth=2, slots=5, node_capacity=32,
                    decode_capacity=16, temperature=0.0,
                    ctx_store=ctx_store, page_size=8)
        base.update(kw)
        return TreeServeEngine(model, cfg, TreeConfig(**base))

    return forest, tree, params


@pytest.fixture(scope="module")
def req_tokens(small_model):
    cfg = small_model[0]
    rng = np.random.RandomState(0)
    return {
        "sys": jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 12))),
        "a": jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 9))),
        "b": jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 7))),
    }


@pytest.mark.slow
@pytest.mark.parametrize("cache_dtype,use_kernel", [
    ("bfloat16", True), ("int8", True), ("bfloat16", False),
])
def test_forest_engine_paged_matches_dense(small_model, req_tokens,
                                           cache_dtype, use_kernel):
    """ISSUE acceptance: ctx_store="paged" serves the exact dense-forest
    workload — greedy tokens identical across admit/decode, kernel and
    einsum paths, bf16 and int8 pools."""
    forest, _, params = _engines(small_model)
    outs = {}
    for store in ("dense", "paged"):
        eng = forest(store, cache_dtype=cache_dtype, use_kernel=use_kernel)
        st = eng.init_state()
        st, _ = eng.admit(params, st, req_tokens["a"], 3)
        st, _ = eng.admit(params, st, req_tokens["b"], 2)
        st = eng.step_chunk(params, st, 6)
        outs[store] = [eng.outputs[s] for s in range(5)]
    assert outs["dense"] == outs["paged"]


@pytest.mark.slow
def test_tree_engine_paged_reuse_refcounts_and_release(small_model,
                                                       req_tokens):
    """Paged trie serving end-to-end: greedy tokens match the dense tree
    engine; reused ancestors allocate NO new pages; retirement returns
    leaf pages to the allocator while the shared root's pages survive;
    release_retired structurally shrinks the live-page stream; decode
    compiles ONCE across admit/step/retire/readmit."""
    _, tree, params = _engines(small_model)
    d = tree("dense")
    ds = d.init_state()
    ds, _ = d.admit(params, ds, [req_tokens["sys"], req_tokens["a"]], 2)
    ds = d.step_chunk(params, ds, 4)

    p = tree("paged")
    ps = p.init_state()
    ps, slots_a = p.admit(params, ps, [req_tokens["sys"], req_tokens["a"]], 2)
    ps = p.step_chunk(params, ps, 4)
    assert [p.outputs[s] for s in slots_a] == \
        [d.outputs[s] for s in range(2)]

    used_after_a = p.num_pages - p.page_alloc.free_count()
    # second request shares [sys]: only the new leaf allocates pages
    ps, slots_b = p.admit(params, ps, [req_tokens["sys"], req_tokens["b"]], 2)
    leaf_pages = (p.num_pages - p.page_alloc.free_count()) - used_after_a
    assert leaf_pages == pages_needed(int(req_tokens["b"].shape[1]),
                                      p.tcfg.page_size)
    ps = p.step_chunk(params, ps, 4)

    # force-retire request A: its leaf's pages free, the shared root's stay
    ps = dataclasses.replace(
        ps, active=ps.active & ~jnp.isin(jnp.arange(5),
                                         jnp.asarray(slots_a)))
    free_before = p.page_alloc.free_count()
    assert p.retire_requests(ps) == [0]
    a_leaf_pages = pages_needed(int(req_tokens["a"].shape[1]),
                                p.tcfg.page_size)
    assert p.page_alloc.free_count() == free_before + a_leaf_pages
    assert p.node_live[0]                       # root survives (refcounted)

    # release_retired: freed node's pages leave the decode stream
    from repro.kernels.ops import live_page_list

    before = int(live_page_list(ps.cache.store.page_tables,
                                ps.cache.store.seg_lens,
                                p.tcfg.page_size)[2][0])
    ps = p.release_retired(ps)
    after = int(live_page_list(ps.cache.store.page_tables,
                               ps.cache.store.seg_lens,
                               p.tcfg.page_size)[2][0])
    assert after == before - a_leaf_pages

    # readmit A: node + pages recycle, decode never recompiles
    ps, slots_c = p.admit(params, ps, [req_tokens["sys"], req_tokens["a"]], 2)
    ps = p.step_chunk(params, ps, 4)
    assert p._chunk._cache_size() == 1          # ONE compile throughout
    fresh = tree("paged")
    fs = fresh.init_state()
    fs, fslots = fresh.admit(params, fs,
                             [req_tokens["sys"], req_tokens["a"]], 2)
    fs = fresh.step_chunk(params, fs, 4)
    for s_new, s_fresh in zip(slots_c, fslots):
        assert p.outputs[s_new] == fresh.outputs[s_fresh]


@pytest.mark.slow
@pytest.mark.parametrize("cache_dtype,use_kernel", [
    ("bfloat16", True), ("int8", True), ("bfloat16", False),
])
def test_serve_engine_paged_matches_dense(small_model, cache_dtype,
                                          use_kernel):
    """The single-prefix ServeEngine under ctx_store="paged" (the
    serve.py prefill_shared -> PagedBifurcatedCache branch): greedy
    tokens identical to the dense engine through the jitted scan decode,
    kernel and einsum paths, bf16 and int8 pools. The BifurcationPolicy
    gate still applies, so the context must be large enough to bifurcate
    — asserted so this test can't silently degrade to DecodeCache."""
    from repro.configs import ServeConfig
    from repro.core.paged import PagedBifurcatedCache
    from repro.runtime.serve import ServeEngine

    cfg, model, params = small_model
    # the reduced config needs ~(b=8, m_c=2048) to cross the policy's 1 MB
    # modelled-saving threshold (see BifurcationPolicy)
    ctx = jnp.asarray(np.random.RandomState(5).randint(
        0, cfg.vocab_size, (1, 2000)))
    outs = {}
    for store in ("dense", "paged"):
        eng = ServeEngine(model, cfg, ServeConfig(
            batch=8, decode_capacity=8, temperature=0.0,
            cache_dtype=cache_dtype, use_kernel=use_kernel,
            ctx_store=store, page_size=128))
        assert eng.should_bifurcate(8, int(ctx.shape[1]))
        _, cache = eng.prefill_shared(params, ctx, 8)
        if store == "paged":
            assert isinstance(cache, PagedBifurcatedCache)
            assert cache.store.num_pages == 16   # ceil(2000/128), exact fit
            assert int(cache.context_len) == 2000
        outs[store] = eng.generate(params, ctx, n_steps=5).tokens
    np.testing.assert_array_equal(np.asarray(outs["dense"]),
                                  np.asarray(outs["paged"]))


@pytest.mark.slow
def test_admit_clears_stale_tables_no_page_aliasing(small_model,
                                                    req_tokens):
    """Pages released at retire may be re-allocated by the very next
    admit; admit must clear the retired segments' stale table rows FIRST,
    so no pool page is ever referenced by two segments and the page walk
    never streams a page twice (n_live == the new segment's pages only)."""
    forest, _, params = _engines(small_model)
    eng = forest("paged", num_pages=2)
    st = eng.init_state()
    st, slots = eng.admit(params, st, req_tokens["a"], 2)   # 9 tok, 2 pages
    # force-retire group 0; its 2 pages return to the allocator but the
    # device table row still references them
    st = dataclasses.replace(st, active=jnp.zeros_like(st.active))
    assert eng.retire_groups(st) == [0]
    assert eng.page_alloc.free_count() == 2
    # next admit re-allocates those SAME pages into group 1
    st, _ = eng.admit(params, st, req_tokens["b"], 2)       # 7 tok, 1 page
    n_live = int(live_page_list(st.cache.store.page_tables,
                                st.cache.store.seg_lens,
                                eng.fcfg.page_size)[2][0])
    assert n_live == 1          # ONLY the new segment's page streams
    tables = np.asarray(st.cache.store.page_tables)
    live_ids = tables[tables >= 0]
    assert len(live_ids) == len(set(live_ids))   # no page owned twice


@pytest.mark.slow
def test_admission_rejection_capacity_and_pool(small_model, req_tokens):
    """Satellite: engines REJECT (clear errors) instead of silently
    truncating/overflowing — context > segment envelope (dense AND paged)
    and context > allocatable pool pages (paged oversubscription)."""
    cfg = small_model[0]
    forest, tree, params = _engines(small_model)
    long_ctx = jnp.zeros((1, 33), jnp.int32)    # > ctx_capacity = 32

    for store in ("dense", "paged"):
        eng = forest(store)
        st = eng.init_state()
        with pytest.raises(ValueError, match="exceeds the segment capacity"):
            eng.admit(params, st, long_ctx, 1)

    # oversubscribed pool: 2 segments' envelope but only 2 pages of 8
    eng = forest("paged", num_pages=2)
    st = eng.init_state()
    st, _ = eng.admit(params, st, req_tokens["a"], 2)   # 9 tok -> 2 pages
    with pytest.raises(RuntimeError, match="free — retire first"):
        eng.admit(params, st, req_tokens["b"], 1)

    teng = tree("paged", num_pages=2)
    ts = teng.init_state()
    with pytest.raises(RuntimeError, match="pool pages"):
        teng.admit(params, ts, [req_tokens["sys"], req_tokens["a"]], 1)
    # rejection happened BEFORE any state mutation: a fitting request lands
    ts, _ = teng.admit(params, ts, [req_tokens["b"]], 1)
    assert teng.node_live[0]


# ---------------------------------------------------------------------------
# Hardened allocator: atomic mutators + invariant auditing (PR 6)
# ---------------------------------------------------------------------------

def test_allocator_typed_errors_are_backward_compatible():
    """The new taxonomy subclasses the historical bare types, so existing
    ``except RuntimeError`` / ``except ValueError`` sites keep working."""
    from repro.core.errors import (
        AllocatorCorruption,
        CapacityError,
        PoolExhausted,
        SegmentCapacityExceeded,
    )

    assert issubclass(PoolExhausted, RuntimeError)
    assert issubclass(PoolExhausted, CapacityError)
    assert PoolExhausted.retryable
    assert issubclass(SegmentCapacityExceeded, ValueError)
    assert not SegmentCapacityExceeded.retryable
    assert issubclass(AllocatorCorruption, RuntimeError)

    al = PageAllocator(2)
    al.alloc(2)
    with pytest.raises(PoolExhausted):
        al.alloc(1)
    st = PagedKVStore.init(1, 2, 2, 8, G, HD, page_m=8)
    k = jnp.ones((1, 17, G, HD), jnp.bfloat16)
    with pytest.raises(SegmentCapacityExceeded):
        st.write_segment(k, k, 0, [0, 1, 2])


def test_allocator_alloc_atomic_on_exhaustion():
    """A rejected alloc grabs NOTHING: free list and refcounts untouched."""
    from repro.core.errors import PoolExhausted

    al = PageAllocator(4)
    al.alloc(3)
    before = al.free_pages()
    with pytest.raises(PoolExhausted):
        al.alloc(2)
    assert al.free_pages() == before
    assert al.alloc(1) == before                 # the survivor still works
    with pytest.raises(ValueError):
        al.alloc(-1)


def test_allocator_double_release_refused_atomically():
    """Double release (across calls AND duplicated within one call) raises
    AllocatorCorruption BEFORE mutating — the historical bug silently
    pushed the page onto the free list twice, aliasing HBM."""
    from repro.core.errors import AllocatorCorruption

    al = PageAllocator(4)
    a = al.alloc(2)
    assert al.release([a[0]]) == [a[0]]
    before = (al.free_pages(), al.free_count())
    with pytest.raises(AllocatorCorruption, match="double release"):
        al.release([a[0]])                       # already free
    with pytest.raises(AllocatorCorruption, match="double release"):
        al.release([a[1], a[1]])                 # dup within one call
    assert (al.free_pages(), al.free_count()) == before
    al.audit()                                   # invariants intact


def test_allocator_release_and_share_validate_ids():
    """Unknown page ids and shares of free pages are refused atomically."""
    from repro.core.errors import AllocatorCorruption

    al = PageAllocator(4)
    a = al.alloc(2)
    for bad in (99, -1, "x"):
        with pytest.raises(AllocatorCorruption, match="unknown page"):
            al.release([bad])
        with pytest.raises(AllocatorCorruption, match="unknown page"):
            al.share([bad])
    free = al.free_pages()[0]
    with pytest.raises(AllocatorCorruption, match="share of free page"):
        al.share([free])
    # a failed share mid-list increments NOTHING
    with pytest.raises(AllocatorCorruption):
        al.share([a[0], free])
    assert al.release(a) == a                    # refcounts were untouched
    al.audit()


def test_allocator_accepts_numpy_page_ids():
    """Engine mirrors hand back np.int32/int64 ids — the allocator must
    treat them as the same page, not 'unknown'."""
    al = PageAllocator(4)
    a = al.alloc(2)
    al.share(np.asarray(a, np.int32))
    al.release(np.asarray(a, np.int64))
    assert al.release(list(np.asarray(a, np.int32))) == a
    assert al.free_count() == 4
    al.audit()


def test_allocator_audit_catches_planted_corruption():
    """audit() re-derives every invariant from scratch: free-list damage,
    refcount drift, aliased live rows, out-of-pool rows, and host-mirror
    multiset mismatches each raise AllocatorCorruption."""
    from repro.core.errors import AllocatorCorruption

    def fresh():
        al = PageAllocator(4)
        ids = al.alloc(2)
        return al, ids

    al, ids = fresh()
    assert al.audit(rows=[np.asarray([ids[0], -1]),
                          np.asarray([ids[1]])],
                    tracked=ids) is True

    al, ids = fresh()
    al._free.append(ids[0])                      # resurrect a held page
    with pytest.raises(AllocatorCorruption, match="free list"):
        al.audit()

    al, ids = fresh()
    al._refs[ids[0]] = -1                        # refcount drift
    with pytest.raises(AllocatorCorruption, match="negative refcount"):
        al.audit()

    al, ids = fresh()                            # two live rows, one page
    with pytest.raises(AllocatorCorruption, match="two live segments"):
        al.audit(rows=[np.asarray([ids[0]]), np.asarray([ids[0]])])

    al, ids = fresh()                            # row points outside pool
    with pytest.raises(AllocatorCorruption, match="outside the pool"):
        al.audit(rows=[np.asarray([7])])

    al, ids = fresh()                            # row references free page
    free = al.free_pages()[0]
    with pytest.raises(AllocatorCorruption, match="FREE"):
        al.audit(rows=[np.asarray([free])])

    al, ids = fresh()                            # mirror lost a page
    with pytest.raises(AllocatorCorruption, match="host mirrors"):
        al.audit(tracked=[ids[0]])
