"""Hierarchical prefix-trie (cascade) decoding + TreeServeEngine.

Covers the tentpole acceptance criteria beyond the bit-identity reductions
(which live in tests/test_differential.py):
  * tree caches (bf16 + int8): write_node admission, path assignment /
    slot reuse, per-slot context lengths, spec surfaces, both layouts;
  * multi-level correctness: the tree kernel AND the cascade einsum
    reference against a per-slot concatenated-context oracle on a real
    depth-2/3 trie with node reuse across paths and -1 (unused) levels;
  * structural no-HBM-spill for the tree kernels (bf16 + the q8 no-dequant
    guarantee) and tree sharding specs;
  * TreeServeEngine end-to-end: depth-1 admission serves the EXACT
    flat-forest workload (greedy tokens identical to ForestServeEngine,
    einsum and kernel paths), longest-matching-prefix node reuse, decode
    compiles once across admits, refcounted retirement;
  * per-node IO accounting (core.io_model.tree_decode_io_bytes): the L=3
    trie beats the flat-forest replay of the same traffic.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_no_hbm_spill, make_decode_case
from repro.configs import ForestConfig, TreeConfig, get_config, reduced_config
from repro.core.kv_cache import PrefixTreeCache
from repro.core.quantized import QuantPrefixTreeCache, quantize_ctx
from repro.models import get_model
from repro.runtime.serve import ForestServeEngine, TreeServeEngine

pytestmark = pytest.mark.slow  # CI runs the slow tier in its own step

G, HD = 2, 32

CFG = reduced_config(get_config("internlm2-1.8b"))
MODEL = get_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.RandomState(0)
SYS = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 12)))      # shared root
TPL = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 6)))       # template
REQ_A = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 9)))
REQ_B = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 7)))


def _tree(n_nodes=4, depth=2, slots=5, cache_dtype="bfloat16",
          use_kernel=False, **kw):
    tcfg = TreeConfig(n_nodes=n_nodes, depth=depth, slots=slots,
                      node_capacity=32, decode_capacity=16, temperature=0.0,
                      cache_dtype=cache_dtype, use_kernel=use_kernel, **kw)
    return TreeServeEngine(MODEL, CFG, tcfg)


def _forest(n_groups=2, slots=5, cache_dtype="bfloat16", use_kernel=False,
            ctx_capacity=32, **kw):
    fcfg = ForestConfig(n_groups=n_groups, slots=slots,
                        ctx_capacity=ctx_capacity, decode_capacity=16,
                        temperature=0.0, cache_dtype=cache_dtype,
                        use_kernel=use_kernel, **kw)
    return ForestServeEngine(MODEL, CFG, fcfg)


# ---------------------------------------------------------------------------
# Tree caches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["gmk", "mgk"])
def test_tree_cache_write_node_and_lens(layout):
    cache = PrefixTreeCache.init(2, 3, 2, 4, 32, 8, 2, 16, ctx_layout=layout)
    k = jnp.ones((2, 20, 2, 16), jnp.float32)
    cache = cache.write_node(k, k * 2, 1)
    assert int(cache.node_lens[1]) == 20 and int(cache.node_lens[0]) == 0
    seg = cache.k_ctx[:, 1]
    live = seg[:, :, :20] if layout == "gmk" else seg[:, :20]
    dead = seg[:, :, 20:] if layout == "gmk" else seg[:, 20:]
    assert float(jnp.min(jnp.abs(live))) > 0          # node written
    assert float(jnp.max(jnp.abs(dead))) == 0         # capacity tail zero
    assert float(jnp.max(jnp.abs(cache.k_ctx[:, 0]))) == 0  # others intact


def test_tree_cache_assign_paths_wipes_stale_decode_arm():
    cache = PrefixTreeCache.init(1, 4, 2, 4, 16, 8, 2, 16)
    cache = dataclasses.replace(
        cache, k_dec=jnp.ones_like(cache.k_dec),
        dec_lens=jnp.full((4,), 5, jnp.int32),
        paths=jnp.asarray([[0, 0, 1, 1], [2, -1, 3, -1]], jnp.int32))
    mask = jnp.asarray([False, True, True, False])
    cache = cache.assign_paths(mask, jnp.asarray([1, 3], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(cache.paths), [[0, 1, 1, 1], [2, 3, 3, -1]])
    np.testing.assert_array_equal(np.asarray(cache.dec_lens), [5, 0, 0, 5])
    assert float(jnp.max(jnp.abs(cache.k_dec[:, 1]))) == 0   # wiped
    assert float(jnp.min(jnp.abs(cache.k_dec[:, 0]))) == 1   # kept


def test_tree_cache_slot_context_lens_sums_path():
    cache = PrefixTreeCache.init(1, 4, 3, 3, 32, 8, 2, 16)
    k20 = jnp.ones((1, 20, 2, 16), jnp.float32)
    k7 = jnp.ones((1, 7, 2, 16), jnp.float32)
    cache = cache.write_node(k20, k20, 0).write_node(k7, k7, 2)
    cache = dataclasses.replace(
        cache, paths=jnp.asarray(
            [[0, 0, -1], [2, -1, -1], [-1, -1, -1]], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(cache.slot_context_lens()), [27, 20, 0])


@pytest.mark.parametrize("fam", [PrefixTreeCache, QuantPrefixTreeCache])
def test_tree_cache_spec_matches_init(fam):
    spec = fam.spec(2, 3, 2, 4, 32, 8, 2, 16)
    real = fam.init(2, 3, 2, 4, 32, 8, 2, 16)
    assert jax.tree.structure(spec) == jax.tree.structure(real)
    for s, r in zip(jax.tree.leaves(spec), jax.tree.leaves(real)):
        assert s.shape == r.shape and s.dtype == r.dtype
    assert spec.n_nodes == 3 and spec.depth == 2
    assert spec.node_capacity == 32 and spec.n_slots == 4
    assert spec.decode_capacity == 8


def test_tree_quant_cache_quantizes_at_admission():
    cache = QuantPrefixTreeCache.init(2, 2, 2, 4, 32, 8, 2, 16)
    rng = np.random.RandomState(3)
    k = jnp.asarray(rng.randn(2, 20, 2, 16), jnp.float32)
    cache = cache.write_node(k, k, 0)
    assert cache.k_ctx.dtype == jnp.int8
    assert int(cache.node_lens[0]) == 20
    # k scales carry the logit fold: smaller than the raw v scales
    ks = np.asarray(cache.k_scale[:, 0, :, :20])
    vs = np.asarray(cache.v_scale[:, 0, :, :20])
    assert ks.min() > 0 and np.all(ks < vs)
    np.testing.assert_allclose(ks * 16**0.5, vs, rtol=1e-5)


# ---------------------------------------------------------------------------
# Multi-level correctness vs the concatenated-context oracle
# ---------------------------------------------------------------------------

def _trie_case(dtype=jnp.float32, seed=7):
    """A real depth-2 trie over 4 nodes with node reuse and one depth-1
    slot: node 0 = shared root, nodes 1/2 = leaves, node 3 = a standalone
    single-level prefix."""
    rng = np.random.RandomState(seed)
    b, p, n, c_d = 5, 2, 1, 8
    n_nodes, cap = 4, 96
    case = {
        "q": jnp.asarray(rng.randn(b, G, p, n, HD), dtype),
        "kc": jnp.asarray(rng.randn(n_nodes, G, cap, HD), dtype),  # gmk
        "vc": jnp.asarray(rng.randn(n_nodes, G, cap, HD), dtype),
        "kd": jnp.asarray(rng.randn(b, c_d, G, HD), dtype),
        "vd": jnp.asarray(rng.randn(b, c_d, G, HD), dtype),
        "mask": jnp.arange(c_d)[None, :] < jnp.asarray(
            rng.randint(1, c_d + 1, size=(b,)))[:, None],
        "node_lens": jnp.asarray([64, 96, 37, 50], jnp.int32),
        # slots 0/3 share path (0,1); slot 2 shares the root via (0,2);
        # slot 4 is a depth-1 path on node 3 (level 1 unused: -1)
        "paths": jnp.asarray([[0, 0, 0, 0, 3],
                              [1, 2, 2, 1, -1]], jnp.int32),
    }
    return case


def _oracle_per_slot(case, out, rtol=1e-5, atol=1e-5):
    """Check ``out`` slot-by-slot against the single-prefix fused kernel on
    the CONCATENATION of the slot's path nodes."""
    from repro.kernels.ops import bifurcated_decode_attention

    paths = np.asarray(case["paths"])
    lens = np.asarray(case["node_lens"])
    for s in range(out.shape[0]):
        ks, vs = [], []
        for lvl in range(paths.shape[0]):
            nid = paths[lvl, s]
            if nid < 0:
                continue
            ks.append(case["kc"][nid, :, :lens[nid]])
            vs.append(case["vc"][nid, :, :lens[nid]])
        ref = bifurcated_decode_attention(
            case["q"][s:s + 1], jnp.concatenate(ks, axis=1),
            jnp.concatenate(vs, axis=1), case["kd"][s:s + 1],
            case["vd"][s:s + 1], case["mask"][s:s + 1],
            block_m=64, interpret=True, ctx_layout="gmk")
        np.testing.assert_allclose(np.asarray(out[s:s + 1]),
                                   np.asarray(ref), rtol=rtol, atol=atol)


def test_tree_kernel_multi_level_vs_concat_oracle():
    from repro.kernels.ops import tree_bifurcated_decode_attention

    case = _trie_case()
    out = tree_bifurcated_decode_attention(
        case["q"], case["kc"], case["vc"], case["paths"], case["node_lens"],
        case["kd"], case["vd"], case["mask"],
        block_m=64, interpret=True, ctx_layout="gmk")
    _oracle_per_slot(case, out)


def test_tree_einsum_multi_level_vs_concat_oracle():
    from repro.core.bifurcated import tree_bifurcated_attention

    case = _trie_case()
    out = tree_bifurcated_attention(
        case["q"], case["kc"], case["vc"], case["paths"], case["node_lens"],
        case["kd"], case["vd"], decode_mask=case["mask"], ctx_layout="gmk")
    _oracle_per_slot(case, out)


def test_tree_duplicate_node_in_path_set_semantics():
    """A node id repeated at several levels of one path contributes ONCE
    (set semantics): the kernel's OR-membership dedupes by construction
    and the einsum references mask duplicated levels to match — both must
    equal the single-occurrence path exactly."""
    from repro.core.bifurcated import tree_bifurcated_attention
    from repro.kernels.ops import tree_bifurcated_decode_attention

    case = _trie_case()
    dup = jnp.asarray([[0, 0, 0, 0, 3], [0, 0, 0, 0, 3]], jnp.int32)
    single = jnp.asarray([[0, 0, 0, 0, 3], [-1, -1, -1, -1, -1]], jnp.int32)
    args = (case["kc"], case["vc"])
    out_dup_k = tree_bifurcated_decode_attention(
        case["q"], *args, dup, case["node_lens"], case["kd"], case["vd"],
        case["mask"], block_m=64, interpret=True, ctx_layout="gmk")
    out_one_k = tree_bifurcated_decode_attention(
        case["q"], *args, single, case["node_lens"], case["kd"], case["vd"],
        case["mask"], block_m=64, interpret=True, ctx_layout="gmk")
    np.testing.assert_array_equal(np.asarray(out_dup_k),
                                  np.asarray(out_one_k))
    out_dup_e = tree_bifurcated_attention(
        case["q"], *args, dup, case["node_lens"], case["kd"], case["vd"],
        decode_mask=case["mask"], ctx_layout="gmk")
    out_one_e = tree_bifurcated_attention(
        case["q"], *args, single, case["node_lens"], case["kd"], case["vd"],
        decode_mask=case["mask"], ctx_layout="gmk")
    np.testing.assert_array_equal(np.asarray(out_dup_e),
                                  np.asarray(out_one_e))
    np.testing.assert_allclose(np.asarray(out_dup_k), np.asarray(out_dup_e),
                               rtol=1e-5, atol=1e-5)


def test_tree_q8_multi_level_kernel_vs_einsum():
    """Same scale-folded math, different execution order: the q8 kernel and
    the q8 cascade einsum reference agree at fp32 tightness on f32 inputs;
    both stay within int8 rounding of the unquantized kernel."""
    from repro.core.quantized import tree_bifurcated_attention_q8
    from repro.kernels.ops import (
        tree_bifurcated_decode_attention,
        tree_bifurcated_decode_attention_q8,
    )

    case = _trie_case()
    kq, ks = quantize_ctx(case["kc"], fold_scale=HD**-0.5)
    vq, vs = quantize_ctx(case["vc"])
    out_k = tree_bifurcated_decode_attention_q8(
        case["q"], kq, vq, ks, vs, case["paths"], case["node_lens"],
        case["kd"], case["vd"], case["mask"],
        block_m=64, interpret=True, ctx_layout="gmk")
    out_e = tree_bifurcated_attention_q8(
        case["q"], kq, vq, ks, vs, case["paths"], case["node_lens"],
        case["kd"], case["vd"], decode_mask=case["mask"], ctx_layout="gmk")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_e),
                               rtol=1e-5, atol=1e-5)
    out_fp = tree_bifurcated_decode_attention(
        case["q"], case["kc"], case["vc"], case["paths"], case["node_lens"],
        case["kd"], case["vd"], case["mask"],
        block_m=64, interpret=True, ctx_layout="gmk")
    scale = max(float(np.max(np.abs(np.asarray(out_fp)))), 1.0)
    assert float(np.max(np.abs(np.asarray(out_k) - np.asarray(out_fp)))) \
        <= 3e-2 * scale


# ---------------------------------------------------------------------------
# Structural + sharding
# ---------------------------------------------------------------------------

def test_tree_bf16_kernel_no_hbm_spill():
    """The tree (cascade) bf16 kernel keeps the fused-kernel guarantee:
    ONE pallas_call, one normalized bf16 output, no fp32 partials."""
    from repro.kernels.ops import tree_bifurcated_decode_attention

    case = make_decode_case(2, 2, 64, 8, g=2, hd=32, dtype=jnp.bfloat16,
                            seed=1, full_mask=True)
    paths = jnp.zeros((2, 2), jnp.int32)   # depth-2 table, both levels node 0
    clens = jnp.asarray([64], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: tree_bifurcated_decode_attention(
            *a, interpret=True, ctx_layout="mgk")
    )(case["q"], case["kc"][None], case["vc"][None], paths, clens,
      case["kd"], case["vd"], case["mask"]).jaxpr
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16)


def test_tree_q8_kernel_no_dequant_in_hbm():
    """The q8 tree kernel keeps the no-dequant guarantee: node K/V enter
    the pallas_call exclusively as int8; only q + the bf16 decode arm
    carry a head_dim axis as float operands."""
    from repro.kernels.ops import tree_bifurcated_decode_attention_q8

    case = make_decode_case(2, 2, 70, 8, g=2, hd=32, dtype=jnp.bfloat16,
                            seed=2, full_mask=True)
    kq, ks = quantize_ctx(case["kc"], fold_scale=HD**-0.5)
    vq, vs = quantize_ctx(case["vc"])
    paths = jnp.zeros((1, 2), jnp.int32)
    clens = jnp.asarray([70], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: tree_bifurcated_decode_attention_q8(
            *a, interpret=True, ctx_layout="mgk")
    )(case["q"], kq[None], vq[None], ks[None], vs[None], paths, clens,
      case["kd"], case["vd"], case["mask"]).jaxpr
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16, hd=32, q8=True)


@pytest.mark.parametrize("ctx_quant", ["none", "int8"])
@pytest.mark.parametrize("layout", ["gmk", "mgk"])
def test_tree_cache_pspec_tree_layout_aware(ctx_quant, layout):
    from repro.core.quantized import tree_cache_family
    from repro.launch.steps import cache_pspec_tree

    fam = tree_cache_family(ctx_quant)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = fam.spec(2, 3, 2, 4, 64, 8, 2, 16, ctx_layout=layout)
    ps = cache_pspec_tree(mesh, spec)
    ctx_dim = 3 if layout == "gmk" else 2
    assert ps.k_ctx[ctx_dim] == "model"          # node seq dim sharded
    assert all(ax is None for i, ax in enumerate(ps.k_ctx) if i != ctx_dim)
    assert ps.k_dec[2] == "model"
    if ctx_quant == "int8":
        assert ps.k_scale[ctx_dim] == "model"    # scales follow the values
    assert ps.node_lens == jax.sharding.PartitionSpec()
    assert ps.paths == jax.sharding.PartitionSpec()


def test_tree_decode_cache_specs_build_and_decode():
    """launch.specs.tree_decode_cache_specs round-trips through an actual
    jitted decode_step (einsum path) without recompiling per admit."""
    from repro.launch import specs as S

    io = S.tree_decode_cache_specs(CFG, MODEL, slots=3, n_nodes=2, depth=2,
                                   node_capacity=32, dec_capacity=8)
    assert io["cache"].n_nodes == 2 and io["cache"].depth == 2
    assert io["tokens"].shape == (3, 1)
    # abstract spec lowers: eval_shape the decode step
    out = jax.eval_shape(
        lambda p, c, t: MODEL.decode_step(p, c, t, None),
        jax.eval_shape(MODEL.init, jax.random.PRNGKey(0)),
        io["cache"], io["tokens"])
    logits, cache2 = out
    assert logits.shape[0] == 3
    assert cache2.k_dec.shape == io["cache"].k_dec.shape


# ---------------------------------------------------------------------------
# TreeServeEngine end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cache_dtype,use_kernel", [
    ("bfloat16", False), ("bfloat16", True),
    ("int8", False), ("int8", True),
])
def test_tree_engine_depth1_matches_forest(cache_dtype, use_kernel):
    """ISSUE acceptance: with every request a single segment (depth-1
    paths) the tree engine serves the EXACT flat-forest workload — greedy
    tokens identical to ForestServeEngine, bf16 and int8, einsum and
    kernel decode paths."""
    teng = _tree(n_nodes=2, depth=1, cache_dtype=cache_dtype,
                 use_kernel=use_kernel)
    ts = teng.init_state()
    ts, tsl_a = teng.admit(PARAMS, ts, [REQ_A], 3)
    ts, tsl_b = teng.admit(PARAMS, ts, [REQ_B], 2)
    ts = teng.step_chunk(PARAMS, ts, 7)

    feng = _forest(cache_dtype=cache_dtype, use_kernel=use_kernel)
    fs = feng.init_state()
    fs, fsl_a = feng.admit(PARAMS, fs, REQ_A, 3)
    fs, fsl_b = feng.admit(PARAMS, fs, REQ_B, 2)
    fs = feng.step_chunk(PARAMS, fs, 7)
    for t, f in zip(tsl_a + tsl_b, fsl_a + fsl_b):
        assert teng.outputs[t] == feng.outputs[f]
        np.testing.assert_allclose(teng.logps[t], feng.logps[f],
                                   rtol=1e-5, atol=1e-6)


def test_tree_engine_depth2_close_to_concat_forest():
    """A depth-2 trie request [SYS, REQ] must produce (numerically) the
    same next-token distribution as flat-forest serving of the
    concatenated prompt: the first sampled tokens agree exactly (same
    prefill) and the first decode step's logits agree to bf16 tolerance
    (the cascade merges two context levels where the flat path reads one
    concatenated segment — same math, different reduction order)."""
    teng = _tree(n_nodes=4, depth=2, slots=2)
    ts = teng.init_state()
    ts, slots = teng.admit(PARAMS, ts, [SYS, REQ_A], 2)

    feng = _forest(n_groups=1, slots=2, ctx_capacity=64)
    fs = feng.init_state()
    fs, fslots = feng.admit(PARAMS, fs, jnp.concatenate([SYS, REQ_A], 1), 2)
    # identical prefill => identical first tokens
    assert [teng.outputs[s][0] for s in slots] == \
        [feng.outputs[s][0] for s in fslots]
    lt, _ = MODEL.decode_step(PARAMS, ts.cache, ts.tokens, None)
    lf, _ = MODEL.decode_step(PARAMS, fs.cache, fs.tokens, None)
    lt = np.asarray(lt[:, -1], np.float32)
    lf = np.asarray(lf[:, -1], np.float32)
    scale = max(float(np.max(np.abs(lf))), 1.0)
    assert float(np.max(np.abs(lt - lf))) <= 2e-2 * scale
    np.testing.assert_array_equal(lt.argmax(-1), lf.argmax(-1))


def test_tree_engine_longest_prefix_reuse():
    """Admission matches the longest existing prefix path: a second
    request sharing [SYS] reuses the root node (no new segment, refcount
    bump), a third sharing [SYS, TPL] reuses two levels."""
    eng = _tree(n_nodes=6, depth=3, slots=6)
    st = eng.init_state()
    st, _ = eng.admit(PARAMS, st, [SYS, TPL, REQ_A], 2)
    assert eng.node_live.count(True) == 3
    assert eng.node_refs[:3] == [1, 1, 1]
    st, _ = eng.admit(PARAMS, st, [SYS, REQ_B], 2)       # reuse root only
    assert eng.node_live.count(True) == 4
    assert eng.node_refs[:4] == [2, 1, 1, 1]
    st, _ = eng.admit(PARAMS, st, [SYS, TPL, REQ_B], 2)  # reuse two levels
    assert eng.node_live.count(True) == 5
    assert eng.node_refs[:5] == [3, 2, 1, 1, 1]
    # reused root KV equals what a fresh write would produce: greedy
    # decode for the later admits is tested via logits in the depth-2 test;
    # here assert the device path table agrees with the host mirror
    paths = np.asarray(st.cache.paths)
    np.testing.assert_array_equal(paths[:, 0], [0, 1, 2])   # request 1
    np.testing.assert_array_equal(paths[:, 2], [0, 3, -1])  # request 2
    np.testing.assert_array_equal(paths[:, 4], [0, 1, 4])   # request 3


def test_tree_engine_compiles_once_across_admit_retire():
    """Trie admission state is data, not shape — the jitted decode chunk
    compiles exactly once across admit / step / retire / re-admit cycles,
    including node reuse and node recycling."""
    eng = _tree(n_nodes=4, depth=2, slots=4)
    st = eng.init_state()
    st, slots_a = eng.admit(PARAMS, st, [SYS, REQ_A], 2)
    st = eng.step_chunk(PARAMS, st, 4)
    st, slots_b = eng.admit(PARAMS, st, [SYS, REQ_B], 2)
    st = eng.step_chunk(PARAMS, st, 4)
    # force-retire request A; its leaf frees, the shared root survives
    st = dataclasses.replace(
        st, active=st.active & ~jnp.isin(jnp.arange(4),
                                         jnp.asarray(slots_a)))
    assert eng.retire_requests(st) == [0]
    assert eng.node_refs[0] == 1 and eng.node_live[0]    # root kept
    assert not eng.node_live[1]                          # leaf A freed
    st, slots_c = eng.admit(PARAMS, st, [SYS, REQ_A], 2)
    assert set(slots_c) == set(slots_a)                  # slots reused
    assert eng.node_live[1]                              # node recycled
    st = eng.step_chunk(PARAMS, st, 4)
    assert eng.decode_dispatches == 3
    assert eng._chunk._cache_size() == 1                 # ONE compile
    # readmitted request decodes like a fresh engine (stale arms wiped)
    fresh = _tree(n_nodes=4, depth=2, slots=4)
    fst = fresh.init_state()
    fst, fslots = fresh.admit(PARAMS, fst, [SYS, REQ_A], 2)
    fst = fresh.step_chunk(PARAMS, fst, 4)
    for s_new, s_fresh in zip(slots_c, fslots):
        assert eng.outputs[s_new] == fresh.outputs[s_fresh]


def test_tree_engine_retire_frees_shared_root_last():
    """Refcounted retirement: the shared root frees only when the LAST
    request referencing it retires, and its trie-index entry disappears
    with it (no stale matches against a recycled node id)."""
    eng = _tree(n_nodes=4, depth=2, slots=4)
    st = eng.init_state()
    st, sa = eng.admit(PARAMS, st, [SYS, REQ_A], 2)
    st, sb = eng.admit(PARAMS, st, [SYS, REQ_B], 2)
    st = dataclasses.replace(
        st, active=st.active & ~jnp.isin(jnp.arange(4), jnp.asarray(sa)))
    eng.retire_requests(st)
    assert eng.node_live[0]                      # root still referenced
    st = dataclasses.replace(st, active=jnp.zeros_like(st.active))
    eng.retire_requests(st)
    assert not any(eng.node_live)                # everything freed
    assert eng.node_index == {}                  # index emptied
    # freed slots + nodes admit again
    st, _ = eng.admit(PARAMS, st, [REQ_B], 1)
    assert eng.node_live.count(True) == 1


def test_tree_engine_admit_exhaustion_raises():
    eng = _tree(n_nodes=2, depth=2, slots=2)
    st = eng.init_state()
    st, _ = eng.admit(PARAMS, st, [SYS, REQ_A], 2)
    with pytest.raises(RuntimeError, match="free trie node"):
        eng.admit(PARAMS, st, [SYS, REQ_B], 0)   # root reused, leaf: none
    with pytest.raises(RuntimeError, match="free slots"):
        eng.admit(PARAMS, st, [SYS], 1)          # path reusable, no slots
    with pytest.raises(ValueError, match="levels"):
        eng.admit(PARAMS, st, [SYS, TPL, REQ_A], 1)   # deeper than depth
    with pytest.raises(ValueError, match="node capacity"):
        eng.admit(PARAMS, st, [jnp.zeros((1, 33), jnp.int32)], 1)


# ---------------------------------------------------------------------------
# Per-node IO accounting
# ---------------------------------------------------------------------------

def test_tree_io_bytes_per_node_accounting():
    from repro.core.io_model import (
        forest_decode_io_bytes,
        tree_decode_io_bytes,
    )

    # L=3 trie: shared root + 4 children, 16 slots round-robin
    paths = [(0, 1 + i % 4) for i in range(16)]
    io = tree_decode_io_bytes(paths=paths, node_lens=[2048] * 5, c_d=32,
                              g=8, hd=128)
    assert set(io["per_node"]) == {0, 1, 2, 3, 4}
    # ISSUE acceptance: the trie beats the flat-forest replay of the SAME
    # traffic — the root is read once, not once per distinct path
    assert io["total"] < io["forest_total"]
    assert io["io_saving_vs_forest"] > 1.4
    assert io["io_saving_vs_standard"] > io["io_saving_vs_forest"]
    # depth-1 single node reduces exactly to the G=1 forest (fused) model
    one = tree_decode_io_bytes(paths=[(0,)] * 16, node_lens=[4096], c_d=32,
                               g=8, hd=128)
    fo = forest_decode_io_bytes(group_sizes=[16], ctx_lens=[4096], c_d=32,
                                g=8, hd=128)
    assert one["total"] == fo["total"] == one["forest_total"]
    # flat (depth-1) tries coincide with their forest replay exactly
    flat = tree_decode_io_bytes(paths=[(i % 4,) for i in range(16)],
                                node_lens=[2048] * 4, c_d=32, g=8, hd=128)
    assert flat["total"] == flat["forest_total"]
    # q8 nodes halve the dominant (context) term; unreferenced nodes free
    q8 = tree_decode_io_bytes(paths=paths, node_lens=[2048] * 5, c_d=32,
                              g=8, hd=128, impl="tree_q8")
    assert q8["total"] < io["total"]
    # padded-envelope accounting costs more than live-length and coincides
    # when nodes are full
    env = tree_decode_io_bytes(paths=paths, node_lens=[1024] * 5, c_d=32,
                               g=8, hd=128, node_capacity=2048)
    live = tree_decode_io_bytes(paths=paths, node_lens=[1024] * 5, c_d=32,
                                g=8, hd=128)
    assert env["total"] > live["total"]
    full = tree_decode_io_bytes(paths=paths, node_lens=[2048] * 5, c_d=32,
                                g=8, hd=128, node_capacity=2048)
    assert full["total"] == io["total"]
    # the kernel's grid streams EVERY segment: n_nodes= accounts
    # unreferenced (free) segments in the envelope too
    sparse = tree_decode_io_bytes(paths=paths, node_lens=[2048] * 5,
                                  c_d=32, g=8, hd=128, node_capacity=2048,
                                  n_nodes=8)
    assert len(sparse["per_node"]) == 8
    assert sparse["total"] == full["total"] + 3 * 2 * 8 * 2048 * 128 * 2
