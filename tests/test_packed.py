"""Packed heterogeneous-step kernel + ``step_mode="packed"`` serving.

Fast (kernel/queue) tier:
  * work-queue builder edge cases — empty queue, FREE-segment exclusion,
    single-row prefill chunk, ragged chunk bias, fresh-tile positions —
    and ZERO recompiles across all of them (admissions, retirements and
    chunk growth are runtime data under one compiled envelope);
  * structural streamed-tile counts: the queue streams exactly the live
    pages + ceil(fresh_len/pm) fresh tiles, never dead capacity, and the
    pinned tail never issues a DMA;
  * no-HBM-spill for the packed kernels (bf16 + q8 with a chunk
    attached — the fresh K/V envelopes are the two extra full-dtype
    operands allowed by design);
  * chunk-carrying multi-launch chaining bit-identical to single-launch;
  * the chunk half against a NumPy causal oracle over
    [ancestor pages ⊕ fresh tiles].

Engine (slow) tier:
  * ISSUE acceptance: greedy serve tokens with ``step_mode="packed"``
    BIT-IDENTICAL to ``step_mode="decode"`` across tree x {dense, paged}
    x {bf16, int8} on the reference path (chunked suffix prefill is
    row-for-row exact: masked columns underflow to exactly 0.0);
  * kernel path: bf16 greedy tokens identical; int8 chunk logits within
    reduction-order tolerance of the reference path (online softmax over
    pages vs single-pass — argmax near-ties may flip, same class as the
    documented kernel/einsum divergence);
  * pending-prefill lifecycle: PrefillInFlight on colliding admissions,
    clean abort via cancel_request, host_state guarded while pending,
    packed step compiles ONCE across admits/chunks/activations.

(Decode-only bit-identity to the paged kernel and the 13-impl
cross-check live in tests/test_differential.py.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_no_hbm_spill, build_page_pool, make_decode_case
from repro.configs import TreeConfig, get_config, reduced_config
from repro.core.errors import PrefillInFlight
from repro.core.quantized import quantize_ctx
from repro.kernels.ops import (
    packed_bifurcated_decode_attention,
    packed_bifurcated_decode_attention_q8,
    packed_work_queue,
)
from repro.models import get_model
from repro.runtime.serve import TreeServeEngine

G, HD = 2, 32


# ---------------------------------------------------------------------------
# Work-queue builder: edge cases + zero recompiles (satellite)
# ---------------------------------------------------------------------------

def _queue(seg_lens, tables, pm=8, fresh_len=0, fresh_start=0, fcap=2):
    return packed_work_queue(
        jnp.asarray(tables, jnp.int32), jnp.asarray(seg_lens, jnp.int32),
        pm, fresh_len=jnp.int32(fresh_len),
        fresh_start=jnp.int32(fresh_start), num_fresh_tiles=fcap,
        pseudo_seg=len(seg_lens))


def test_packed_queue_empty():
    """All segments free, no chunk: n_ent == 0 — the grid's early-exit
    envelope streams nothing."""
    kind, seg, pdma, fdma, pos, n_ent, bias = _queue(
        [0, 0, 0], [[-1, -1]] * 3)
    assert int(n_ent[0]) == 0


def test_packed_queue_free_segment_exclusion():
    """FREE segments (len 0) and unallocated table rows contribute no
    entries; live pages keep the paged kernels' (segment, page) order."""
    kind, seg, pdma, fdma, pos, n_ent, bias = _queue(
        [13, 0, 8], [[4, 5, -1], [-1, -1, -1], [2, -1, -1]], pm=8, fcap=1)
    ne = int(n_ent[0])
    assert ne == 3                       # ceil(13/8)=2 + 0 + 1
    np.testing.assert_array_equal(np.asarray(pdma)[:ne], [4, 5, 2])
    np.testing.assert_array_equal(np.asarray(seg)[:ne], [0, 0, 2])
    # ragged tail of segment 0: page 5 keeps only 13 - 8 = 5 live columns
    tail = np.asarray(bias)[1]
    assert (tail[:5] == 0).all() and (tail[5:] < -1e29).all()


def test_packed_queue_single_row_chunk():
    """A 1-token prefill chunk enqueues exactly one fresh tile whose bias
    masks every column past the first, positioned at fresh_start."""
    kind, seg, pdma, fdma, pos, n_ent, bias = _queue(
        [8], [[3]], pm=8, fresh_len=1, fresh_start=21, fcap=2)
    ne = int(n_ent[0])
    assert ne == 2 and int(kind[1]) == 1
    assert int(seg[1]) == 1              # pseudo-segment id == n_seg
    assert int(pos[1]) == 21
    row = np.asarray(bias)[1]
    assert row[0] == 0 and (row[1:] < -1e29).all()


def test_packed_queue_fresh_tile_positions():
    """Multi-tile chunks advance ent_pos by pm per tile and split the
    ragged tail bias at fresh_len."""
    kind, seg, pdma, fdma, pos, n_ent, bias = _queue(
        [8], [[0]], pm=8, fresh_len=13, fresh_start=40, fcap=2)
    ne = int(n_ent[0])
    assert ne == 3
    np.testing.assert_array_equal(np.asarray(kind)[:ne], [0, 1, 1])
    np.testing.assert_array_equal(np.asarray(pos)[1:ne], [40, 48])
    np.testing.assert_array_equal(np.asarray(fdma)[1:ne], [0, 1])
    tail = np.asarray(bias)[2]
    assert (tail[:5] == 0).all() and (tail[5:] < -1e29).all()


def test_packed_queue_streamed_tiles():
    """Structural: within n_ent every entry advances a DMA stream exactly
    once (live pages + fresh tiles); the pinned tail past n_ent revisits
    the same block index, so by the revisit rule it streams NOTHING."""
    kind, seg, pdma, fdma, pos, n_ent, bias = _queue(
        [13, 0, 8], [[4, 5, -1], [-1, -1, -1], [2, -1, -1]],
        pm=8, fresh_len=9, fresh_start=25, fcap=2)
    ne = int(n_ent[0])
    kind, pdma, fdma = (np.asarray(kind), np.asarray(pdma),
                        np.asarray(fdma))
    # interleave the two streams exactly as the grid sees them
    page_stream = [int(pdma[i]) for i in range(ne) if kind[i] == 0]
    fresh_stream = [int(fdma[i]) for i in range(ne) if kind[i] == 1]
    n_page_dma = 1 + int(np.sum(np.asarray(page_stream)[1:]
                                != np.asarray(page_stream)[:-1]))
    n_fresh_dma = 1 + int(np.sum(np.asarray(fresh_stream)[1:]
                                 != np.asarray(fresh_stream)[:-1]))
    assert n_page_dma == 3               # pages 4, 5 (revisited), 2
    assert n_fresh_dma == 2              # tiles 0, 1
    # pinned tail: both streams hold their last index past n_ent
    assert (pdma[ne:] == pdma[ne - 1] if kind[ne - 1] == 0
            else pdma[ne:] == pdma[ne:][0]).all()
    assert (fdma[ne:] == fdma[ne - 1]).all()


def test_packed_queue_zero_recompiles():
    """Satellite acceptance: empty queue, single-row chunk, free-segment
    churn and chunk growth all reuse ONE compiled queue builder — every
    input is traced data under a fixed shape envelope."""
    pm, fcap = 8, 2
    jitted = jax.jit(lambda t, sl, fl, fs: packed_work_queue(
        t, sl, pm, fresh_len=fl, fresh_start=fs,
        num_fresh_tiles=fcap, pseudo_seg=3))
    tables = jnp.asarray([[4, 5, -1], [-1, -1, -1], [2, -1, -1]], jnp.int32)
    variants = [
        ([0, 0, 0], 0, 0),               # empty
        ([17, 0, 8], 0, 0),              # decode-only
        ([17, 0, 8], 1, 21),             # single-row chunk
        ([17, 0, 8], 13, 21),            # multi-tile chunk
        ([8, 0, 0], 16, 8),              # retirement churn, full tiles
    ]
    for sl, fl, fs in variants:
        jitted(tables, jnp.asarray(sl, jnp.int32),
               jnp.int32(fl), jnp.int32(fs))
    assert jitted._cache_size() == 1


def test_packed_dispatch_zero_recompiles():
    """The full packed dispatcher compiles ONCE across decode-only,
    single-row-chunk and multi-tile-chunk steps of the same envelope."""
    case = make_decode_case(3, 1, 24, 4, g=G, hd=HD, dtype=jnp.bfloat16)
    pm = 8
    pad = lambda x: jnp.pad(x, ((0, 0), (0, 0)) + ((0, 0),) * (x.ndim - 2))
    kc = case["kc"].transpose(1, 0, 2)[None]          # (1, g, 24, hd)
    vc = case["vc"].transpose(1, 0, 2)[None]
    (kp, vp), table = build_page_pool([kc, vc], [24], pm)
    seg_lens = jnp.asarray([24], jnp.int32)
    paths = jnp.zeros((1, 3), jnp.int32)
    rng = np.random.RandomState(3)
    qf = jnp.asarray(rng.randn(4, G, 1, HD), jnp.bfloat16)
    kf = jnp.asarray(rng.randn(2 * pm, G, HD), jnp.bfloat16)
    vf = jnp.asarray(rng.randn(2 * pm, G, HD), jnp.bfloat16)

    before = packed_bifurcated_decode_attention._cache_size()
    for fl, fp0 in [(0, -1), (1, 24), (9, 24)]:
        fpos = jnp.where(jnp.arange(4) < max(fl, 1) - 0,
                         fp0 + jnp.arange(4), -1).astype(jnp.int32)
        packed_bifurcated_decode_attention(
            case["q"], kp, vp, table, seg_lens, paths,
            case["kd"], case["vd"], case["mask"],
            q_fresh=qf, k_fresh=kf, v_fresh=vf,
            fresh_len=jnp.int32(fl), fresh_start=jnp.int32(24),
            fresh_pos=fpos, fresh_path=jnp.asarray([0], jnp.int32),
            interpret=True)
    assert packed_bifurcated_decode_attention._cache_size() == before + 1


# ---------------------------------------------------------------------------
# Packed kernel: chunk oracle, no-spill, multi-launch with a chunk
# ---------------------------------------------------------------------------

def _chunk_case(seed=0, m_anc=24, cp=6, buf=3, b=2, c_d=4, pm=8, fcap=2):
    """One packed step mid-prefill: b decode rows over the ancestor
    segment + a cp-row chunk at absolute offset m_anc + buf whose fresh
    envelope holds buf + cp live columns."""
    rng = np.random.RandomState(seed)
    f = lambda *s: rng.randn(*s).astype(np.float32)
    case = {
        "q": jnp.asarray(f(b, G, 1, 1, HD), jnp.float32),
        "kd": jnp.asarray(f(b, c_d, G, HD), jnp.float32),
        "vd": jnp.asarray(f(b, c_d, G, HD), jnp.float32),
        "mask": jnp.ones((b, c_d), bool),
        "kc": jnp.asarray(f(m_anc, G, HD), jnp.float32),
        "vc": jnp.asarray(f(m_anc, G, HD), jnp.float32),
    }
    fresh_len = buf + cp
    kf_live = f(fresh_len, G, HD)
    vf_live = f(fresh_len, G, HD)
    kf = np.zeros((fcap * pm, G, HD), np.float32)
    vf = np.zeros_like(kf)
    kf[:fresh_len], vf[:fresh_len] = kf_live, vf_live
    case.update(
        q_fresh=jnp.asarray(f(cp, G, 1, HD), jnp.float32),
        k_fresh=jnp.asarray(kf), v_fresh=jnp.asarray(vf),
        fresh_len=fresh_len, fresh_start=m_anc,
        fresh_pos=jnp.asarray(m_anc + buf + np.arange(cp), jnp.int32),
        pm=pm)
    return case


def _pool(case, q8=False):
    m_anc, pm = case["kc"].shape[0], case["pm"]
    kc = np.asarray(case["kc"]).transpose(1, 0, 2)[None]
    vc = np.asarray(case["vc"]).transpose(1, 0, 2)[None]
    if q8:
        kq, ks = quantize_ctx(jnp.asarray(kc[0]), fold_scale=HD**-0.5)
        vq, vs = quantize_ctx(jnp.asarray(vc[0]))
        arrays = [np.asarray(kq)[None], np.asarray(vq)[None],
                  np.asarray(ks)[None], np.asarray(vs)[None]]
    else:
        arrays = [kc, vc]
    return build_page_pool(arrays, [m_anc], pm, perm_seed=5)


def _chunk_oracle(case):
    """NumPy single-pass softmax for each chunk row over
    [ancestors ⊕ causally-visible fresh columns]."""
    cp = case["q_fresh"].shape[0]
    m_anc, fl = case["fresh_start"], case["fresh_len"]
    scale = HD**-0.5
    out = np.zeros((cp, G, 1, HD), np.float32)
    kc, vc = np.asarray(case["kc"]), np.asarray(case["vc"])
    kf = np.asarray(case["k_fresh"])[:fl]
    vf = np.asarray(case["v_fresh"])[:fl]
    K = np.concatenate([kc, kf])        # (m_anc + fl, G, HD)
    V = np.concatenate([vc, vf])
    pos = np.concatenate([np.full(m_anc, -1), m_anc + np.arange(fl)])
    for i in range(cp):
        rp = int(case["fresh_pos"][i])
        vis = pos <= rp
        for g in range(G):
            qi = np.asarray(case["q_fresh"])[i, g, 0]
            s = (K[vis, g] @ qi) * scale
            w = np.exp(s - s.max())
            w /= w.sum()
            out[i, g, 0] = w @ V[vis, g]
    return out


def test_packed_chunk_matches_oracle():
    case = _chunk_case()
    (kp, vp), table = _pool(case)
    seg_lens = jnp.asarray([case["kc"].shape[0]], jnp.int32)
    paths = jnp.zeros((1, 2), jnp.int32)
    _, out_fresh = packed_bifurcated_decode_attention(
        case["q"], kp, vp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"],
        q_fresh=case["q_fresh"], k_fresh=case["k_fresh"],
        v_fresh=case["v_fresh"], fresh_len=jnp.int32(case["fresh_len"]),
        fresh_start=jnp.int32(case["fresh_start"]),
        fresh_pos=case["fresh_pos"],
        fresh_path=jnp.asarray([0], jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(out_fresh), _chunk_oracle(case),
                               rtol=2e-5, atol=2e-5)


def test_packed_multi_launch_with_chunk_bit_identical():
    """Chained launches that SPLIT the queue mid-chunk (pages in one
    launch, fresh tiles in the next) reproduce the single launch
    bit-for-bit — raw fp32 state round-trips losslessly."""
    case = _chunk_case(m_anc=24, cp=6, buf=3)
    (kp, vp), table = _pool(case)
    seg_lens = jnp.asarray([24], jnp.int32)
    paths = jnp.zeros((1, 2), jnp.int32)
    kw = dict(
        q_fresh=case["q_fresh"], k_fresh=case["k_fresh"],
        v_fresh=case["v_fresh"], fresh_len=jnp.int32(case["fresh_len"]),
        fresh_start=jnp.int32(case["fresh_start"]),
        fresh_pos=case["fresh_pos"],
        fresh_path=jnp.asarray([0], jnp.int32), interpret=True)
    one = packed_bifurcated_decode_attention(
        case["q"], kp, vp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"], **kw)
    two = packed_bifurcated_decode_attention(
        case["q"], kp, vp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"],
        entries_per_launch=2, **kw)
    for a, b in zip(one, two):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_no_hbm_spill_bf16():
    case = _chunk_case()
    bf = lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x
    (kp, vp), table = _pool(case)
    seg_lens = jnp.asarray([24], jnp.int32)
    paths = jnp.zeros((1, 2), jnp.int32)

    def run(q, kp, vp, kd, vd, qf, kf, vf):
        return packed_bifurcated_decode_attention(
            q, kp, vp, table, seg_lens, paths, kd, vd, case["mask"],
            q_fresh=qf, k_fresh=kf, v_fresh=vf,
            fresh_len=jnp.int32(case["fresh_len"]),
            fresh_start=jnp.int32(case["fresh_start"]),
            fresh_pos=case["fresh_pos"],
            fresh_path=jnp.asarray([0], jnp.int32), interpret=True)

    jaxpr = jax.make_jaxpr(run)(
        bf(case["q"]), bf(kp), bf(vp), bf(case["kd"]), bf(case["vd"]),
        bf(case["q_fresh"]), bf(case["k_fresh"]), bf(case["v_fresh"]))
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16)


def test_packed_no_hbm_spill_q8():
    """q8 with a chunk attached: context K/V enter as int8 only; the
    float hd-carrying operands are exactly q + bf16 decode arm + bf16
    fresh K/V (5) — no dequantized buffer ever reaches HBM."""
    case = _chunk_case()
    bf = lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x
    (kp, vp, ksp, vsp), table = _pool(case, q8=True)
    seg_lens = jnp.asarray([24], jnp.int32)
    paths = jnp.zeros((1, 2), jnp.int32)

    def run(q, kd, vd, qf, kf, vf):
        return packed_bifurcated_decode_attention_q8(
            q, kp, vp, ksp, vsp, table, seg_lens, paths,
            kd, vd, case["mask"],
            q_fresh=qf, k_fresh=kf, v_fresh=vf,
            fresh_len=jnp.int32(case["fresh_len"]),
            fresh_start=jnp.int32(case["fresh_start"]),
            fresh_pos=case["fresh_pos"],
            fresh_path=jnp.asarray([0], jnp.int32), interpret=True)

    jaxpr = jax.make_jaxpr(run)(
        bf(case["q"]), bf(case["kd"]), bf(case["vd"]),
        bf(case["q_fresh"]), bf(case["k_fresh"]), bf(case["v_fresh"]))
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16, hd=HD, q8=True,
                        fresh=True)


# ---------------------------------------------------------------------------
# Engine tier: step_mode="packed" end-to-end
# ---------------------------------------------------------------------------

pytest.importorskip("jax")

CFG = reduced_config(get_config("internlm2-1.8b"))
MODEL = get_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
RNG = np.random.RandomState(0)
SYS = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 12)))
TPL = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 6)))
REQ_A = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 9)))
REQ_B = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 7)))


def _engine(step_mode, **kw):
    tcfg = TreeConfig(**{**dict(
        n_nodes=8, depth=3, slots=6, node_capacity=32, decode_capacity=16,
        temperature=0.0, suffix_prefill=True, prefill_chunk=5,
        step_mode=step_mode), **kw})
    return TreeServeEngine(MODEL, CFG, tcfg)


def _serve(step_mode, spy=None, **kw):
    """Shared workload: a fresh 2-level admission, 6 steps, then a
    PARTIALLY-MATCHED 3-level admission mid-stream, 8 more steps."""
    eng = _engine(step_mode, **kw)
    if spy is not None:
        orig = eng._activate_pending

        def wrap(state, rid, logits0):
            spy.append(np.asarray(logits0, np.float32).ravel())
            return orig(state, rid, logits0)

        eng._activate_pending = wrap
    st = eng.init_state()
    st, sa = eng.admit(PARAMS, st, [SYS, REQ_A], 2)
    st = eng.step_chunk(PARAMS, st, 6)
    st, sb = eng.admit(PARAMS, st, [SYS, TPL, REQ_B], 2)
    st = eng.step_chunk(PARAMS, st, 8)
    return eng, st, sa, sb


@pytest.mark.slow
@pytest.mark.parametrize("ctx_store", ["dense", "paged"])
@pytest.mark.parametrize("cache_dtype", ["bfloat16", "int8"])
def test_packed_serve_bit_identical_to_decode(ctx_store, cache_dtype):
    """ISSUE acceptance: greedy serve tokens with ``step_mode="packed"``
    are BIT-IDENTICAL to ``step_mode="decode"`` (chunk steps displace
    decode steps, so the packed run's output stream is a prefix of the
    decode run's at equal step counts)."""
    kw = dict(ctx_store=ctx_store, cache_dtype=cache_dtype)
    de, _, da, db = _serve("decode", **kw)
    pe, _, pa, pb = _serve("packed", **kw)
    assert not pe._pending
    for sd, sp in zip(da + db, pa + pb):
        od, op = de.outputs[sd], pe.outputs[sp]
        assert len(op) >= 2
        assert od[:len(op)] == op, (sd, od, op)
        np.testing.assert_allclose(de.logps[sd][:len(op)], pe.logps[sp],
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_packed_serve_kernel_bf16_greedy_identical():
    """Kernel path (paged + use_kernel): bf16 greedy tokens match the
    decode-mode run on this workload."""
    kw = dict(ctx_store="paged", use_kernel=True)
    de, _, da, db = _serve("decode", **kw)
    pe, _, pa, pb = _serve("packed", **kw)
    assert not pe._pending
    for sd, sp in zip(da + db, pa + pb):
        od, op = de.outputs[sd], pe.outputs[sp]
        assert len(op) >= 2 and od[:len(op)] == op


@pytest.mark.slow
def test_packed_serve_kernel_q8_logits_close():
    """int8 kernel path: the packed kernel's chunk logits agree with the
    reference path within reduction-order tolerance (online softmax over
    pages + dot-then-scale dequant vs single-pass einsum). Greedy argmax
    may flip on near-ties, so the gate is on logits, not tokens."""
    ref_logits, ker_logits = [], []
    _serve("packed", spy=ref_logits, ctx_store="paged", cache_dtype="int8",
           use_kernel=False)
    eng, _, pa, pb = _serve("packed", spy=ker_logits, ctx_store="paged",
                            cache_dtype="int8", use_kernel=True)
    assert not eng._pending
    assert len(ref_logits) == len(ker_logits) == 2
    for a, b in zip(ref_logits, ker_logits):
        scale = max(float(np.abs(a).max()), 1.0)
        np.testing.assert_allclose(a, b, atol=0.1 * scale)
    # the packed kernel engine still compiled its step exactly once
    assert eng._packed_one._cache_size() == 1


@pytest.mark.slow
def test_packed_prefill_in_flight_and_drain():
    """A second admission whose first NEW segment collides with a node
    still being prefilled raises the retryable PrefillInFlight; once the
    pending chunks land, the same admission succeeds and REUSES the now
    live node (no duplicate trie level)."""
    eng = _engine("packed", ctx_store="paged")
    st = eng.init_state()
    st, sa = eng.admit(PARAMS, st, [SYS, REQ_A], 1)
    assert eng._pending and eng.node_pending
    with pytest.raises(PrefillInFlight) as ei:
        eng.admit(PARAMS, st, [SYS, REQ_B], 1)
    assert ei.value.retryable and ei.value.reason == "prefill_in_flight"
    st = eng.step_chunk(PARAMS, st, 6)       # drain SYS(12)+REQ_A(9) @ 5
    assert not eng._pending and not eng.node_pending
    before = len(eng.free_nodes())
    st, sb = eng.admit(PARAMS, st, [SYS, REQ_B], 1)
    st = eng.step_chunk(PARAMS, st, 4)
    assert len(eng.free_nodes()) == before - 1   # SYS node reused
    assert eng.outputs[sb[0]]


@pytest.mark.slow
def test_packed_abort_pending_and_host_state_guard():
    """cancel_request mid-prefill rolls the reservation back — pending
    nodes freed, pages released, trie index clean — and host_state is
    guarded while a prefill is in flight."""
    eng = _engine("packed", ctx_store="paged")
    st = eng.init_state()
    free0 = len(eng.free_nodes())
    pages0 = eng.page_alloc.free_count()
    st, sa = eng.admit(PARAMS, st, [SYS, REQ_A], 1)
    rid = eng.last_rid
    with pytest.raises(RuntimeError):
        eng.host_state()
    st = eng.cancel_request(st, rid)
    assert not eng._pending and not eng.node_pending
    assert len(eng.free_nodes()) == free0
    assert eng.page_alloc.free_count() == pages0
    assert eng.audit_state(st)
    # engine still serves after the abort
    st, sb = eng.admit(PARAMS, st, [SYS, REQ_B], 1)
    st = eng.step_chunk(PARAMS, st, 6)
    assert not eng._pending and eng.outputs[sb[0]]
    eng.host_state()                         # quiescent: guard lifted
