"""Heartbeat + supervise (runtime/fault_tolerance.py) — host-only, fast.

The serving durability layer (runtime/recovery.py) leans on both: every
pump beats the heartbeat, ``DurableFrontend.pump`` raises
``StaleHeartbeat`` when the beat goes stale, and ``run_supervised`` uses
``supervise`` for the capped-restart / backoff / escalation ladder. This
file pins their exact semantics, including the awkward corners: missing
and malformed heartbeat files, clock skew (a FUTURE timestamp must not
read as stale), the restart cap, exponential backoff with an injected
sleep, and the on_failure recovery hook ordering.
"""
import os
import time

import pytest

from repro.runtime.fault_tolerance import (
    Heartbeat,
    StaleHeartbeat,
    supervise,
)


# ---------------------------------------------------------------------------
# Heartbeat
# ---------------------------------------------------------------------------

def test_heartbeat_beat_then_last(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb"))
    assert hb.last() is None
    hb.beat(7)
    step, ts = hb.last()
    assert step == 7
    assert abs(ts - time.time()) < 5.0
    hb.beat(8)
    assert hb.last()[0] == 8          # overwrites, never appends


def test_heartbeat_missing_file_is_not_stale(tmp_path):
    hb = Heartbeat(str(tmp_path / "never_written"))
    # a process that has not started beating yet is NOT stale — staleness
    # must only ever trigger on genuinely old beats
    assert hb.last() is None
    assert not hb.stale(0.0)


def test_heartbeat_malformed_file_is_not_stale(tmp_path):
    p = tmp_path / "hb"
    p.write_text("garbage not a beat")
    hb = Heartbeat(str(p))
    assert hb.last() is None
    assert not hb.stale(0.0)


def test_heartbeat_staleness_threshold(tmp_path):
    p = tmp_path / "hb"
    hb = Heartbeat(str(p))
    # hand-write an old beat: 100s in the past
    p.write_text(f"3 {time.time() - 100.0}\n")
    assert hb.stale(50.0)
    assert not hb.stale(1000.0)


def test_heartbeat_clock_skew_future_beat_not_stale(tmp_path):
    p = tmp_path / "hb"
    hb = Heartbeat(str(p))
    # clock skew / clock step: the recorded beat is in the FUTURE.
    # (now - ts) is negative, which must never exceed a positive timeout.
    p.write_text(f"3 {time.time() + 3600.0}\n")
    assert not hb.stale(0.5)


def test_heartbeat_creates_parent_dir(tmp_path):
    hb = Heartbeat(str(tmp_path / "deep" / "nested" / "hb"))
    hb.beat(1)
    assert os.path.exists(hb.path)


def test_stale_heartbeat_is_an_exception():
    assert issubclass(StaleHeartbeat, RuntimeError)


# ---------------------------------------------------------------------------
# supervise
# ---------------------------------------------------------------------------

def test_supervise_returns_on_success():
    assert supervise(lambda: 42) == 42


def test_supervise_restart_cap():
    calls = []

    def boom():
        calls.append(1)
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError, match="always fails"):
        supervise(boom, max_restarts=3)
    # initial attempt + 3 restarts, then the cap propagates the error
    assert len(calls) == 4


def test_supervise_recovers_after_transient_failures():
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert supervise(flaky, max_restarts=3) == "ok"
    assert state["n"] == 3


def test_supervise_backoff_exponential_and_capped():
    sleeps = []
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] <= 5:
            raise RuntimeError("x")
        return "done"

    out = supervise(flaky, max_restarts=10, backoff_s=1.0,
                    backoff_cap_s=4.0, sleep=sleeps.append)
    assert out == "done"
    # 1, 2, 4, then capped at 4
    assert sleeps == [1.0, 2.0, 4.0, 4.0, 4.0]


def test_supervise_no_backoff_by_default():
    sleeps = []
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 2:
            raise RuntimeError("x")
        return "ok"

    supervise(flaky, sleep=sleeps.append)
    assert sleeps == []


def test_supervise_on_failure_hook_runs_before_each_retry():
    order = []
    state = {"n": 0}

    def flaky():
        order.append(f"run{state['n']}")
        state["n"] += 1
        if state["n"] < 3:
            raise RuntimeError("x")
        return "ok"

    def on_failure(attempt, exc):
        assert isinstance(exc, RuntimeError)
        order.append(f"recover{attempt}")

    assert supervise(flaky, max_restarts=5, on_failure=on_failure) == "ok"
    assert order == ["run0", "recover1", "run1", "recover2", "run2"]


def test_supervise_on_failure_exception_propagates():
    def boom():
        raise RuntimeError("work failed")

    def bad_recover(attempt, exc):
        raise ValueError("recovery itself failed")

    # a failing recovery hook must escalate immediately, not be retried
    with pytest.raises(ValueError, match="recovery itself failed"):
        supervise(boom, max_restarts=5, on_failure=bad_recover)


def test_supervise_past_cap_does_not_call_hook():
    hook_calls = []

    def boom():
        raise RuntimeError("x")

    with pytest.raises(RuntimeError):
        supervise(boom, max_restarts=2,
                  on_failure=lambda a, e: hook_calls.append(a))
    # the hook prepares a RETRY; past the cap there is no retry to prepare
    assert hook_calls == [1, 2]
