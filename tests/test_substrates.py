"""Substrate tests: checkpointer (atomic/async/restore/elastic), data
pipeline (determinism, sharding, resume), optimizer, schedules, gradient
compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data.pipeline import SyntheticLMDataset, make_pipeline
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.optim.compression import compress_int8_ef, decompress_int8


# ---- checkpointer ----

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,))},
        "opt_state": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last_k=2)
    state = _state()
    ck.save(10, state, blocking=True)
    restored = ck.restore(jax.tree.map(lambda x: jnp.zeros_like(x), state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_last_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last_k=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s), blocking=True)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_ignores_partial_writes(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last_k=3)
    ck.save(5, _state(), blocking=True)
    # simulate a crash mid-save: tmp dir left behind, no meta.json
    os.makedirs(tmp_path / "tmp.9")
    os.makedirs(tmp_path / "step_000000009")  # no meta.json inside
    assert ck.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((8,))},
           "opt_state": {"step": jnp.zeros((), jnp.int32)}}
    with pytest.raises(AssertionError):
        ck.restore(bad)


# ---- data pipeline ----

def test_data_deterministic_by_step():
    d = SyntheticLMDataset(256, 32, seed=3)
    a = d.batch(5, 8)
    b = d.batch(5, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(6, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_partitions_batch():
    d = SyntheticLMDataset(256, 16, seed=0)
    full = d.batch(3, 8, host_id=0, host_count=1)
    h0 = d.batch(3, 8, host_id=0, host_count=2)
    h1 = d.batch(3, 8, host_id=1, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    assert full["tokens"].shape == (8, 16)


def test_data_tokens_in_vocab():
    d = SyntheticLMDataset(100, 64, seed=1)
    b = d.batch(0, 4)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_prefetch_pipeline_resumes():
    d = SyntheticLMDataset(64, 8, seed=0)
    it = make_pipeline(d, 4, start_step=10)
    step, batch = next(it)
    it.close()
    assert step == 10
    np.testing.assert_array_equal(batch["tokens"], d.batch(10, 4)["tokens"])


# ---- optimizer ----

def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([2.0, -3.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip_scales():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw_update(params, huge, opt, lr=1e-3, grad_clip=1.0)
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 1e-2


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)) == 0.0
    assert abs(float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                                     total_steps=100)) - 1.0) < 1e-6
    end = float(cosine_schedule(100, peak_lr=1.0, warmup_steps=10,
                                total_steps=100, min_lr_ratio=0.1))
    assert abs(end - 0.1) < 1e-6


# ---- gradient compression ----

def test_int8_error_feedback_converges():
    grads = {"w": jnp.asarray(np.random.RandomState(0).randn(64) * 0.1)}
    err = None
    acc = jnp.zeros((64,))
    for _ in range(50):
        q, err = compress_int8_ef(grads, err)
        acc = acc + decompress_int8(q)["w"]
    # with error feedback the accumulated quantized sum tracks the true sum
    true = grads["w"] * 50
    rel = float(jnp.max(jnp.abs(acc - true)) / jnp.max(jnp.abs(true)))
    assert rel < 0.02, rel
