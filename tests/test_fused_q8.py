"""Quantized-context fused decode kernel — kernel-specific guarantees.

Exactness sweeps vs the einsum q8 reference and the fp32 oracle moved to
the differential harness (tests/test_differential.py, impls "fused_q8" /
"einsum_q8" / "grouped_q8" on shared conftest fixtures). This file keeps
what is specific to the q8 KERNELS:

  * structural guarantee (conftest.assert_no_hbm_spill(q8=True)): ONE
    pallas_call whose context operands enter as int8 (+ f32 scale vectors,
    no head_dim axis) — no dequantized K_c/V_c buffer exists anywhere in
    the jaxpr, no fp32 partials in HBM — applied to BOTH the single-prefix
    and the grouped (multi-prefix forest) q8 kernels;
  * speculative n > 1 rows against the einsum q8 reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_no_hbm_spill, make_decode_case
from repro.core.quantized import bifurcated_attention_q8, quantize_ctx
from repro.kernels.ops import (
    bifurcated_decode_attention_q8,
    grouped_bifurcated_decode_attention_q8,
)

G, HD = 2, 32


def _quantized(case):
    kq, ks = quantize_ctx(case["kc"], fold_scale=HD**-0.5)  # (m_c, G)
    vq, vs = quantize_ctx(case["vc"])
    return kq, vq, ks, vs


@pytest.mark.parametrize("n", [2, 4])
def test_fused_q8_n_gt_1_speculative_rows(n):
    """Draft-token rows fold into the kernel row dimension like the bf16
    kernel; checked against the einsum q8 reference."""
    case = make_decode_case(3, 2, 100, 12, g=G, hd=HD, n=n, seed=n)
    kq, vq, ks, vs = _quantized(case)
    out = bifurcated_decode_attention_q8(
        case["q"], kq, vq, ks, vs, case["kd"], case["vd"], case["mask"],
        interpret=True, ctx_layout="mgk")
    ref = bifurcated_attention_q8(case["q"], kq, vq, ks, vs,
                                  case["kd"], case["vd"],
                                  decode_mask=case["mask"])
    assert out.shape == case["q"].shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---- structural guarantee: int8 stays int8 all the way into the kernel ----

def _bf16_case():
    case = make_decode_case(2, 2, 64, 8, g=G, hd=HD, seed=1, full_mask=True)
    kq, vq, ks, vs = _quantized(case)
    q = case["q"].astype(jnp.bfloat16)
    kd = case["kd"].astype(jnp.bfloat16)
    vd = case["vd"].astype(jnp.bfloat16)
    return q, kq, vq, ks, vs, kd, vd, case["mask"]


def test_fused_q8_single_pallas_call_no_dequant_in_hbm():
    q, kq, vq, ks, vs, kd, vd, mask = _bf16_case()
    jaxpr = jax.make_jaxpr(
        lambda *a: bifurcated_decode_attention_q8(*a, interpret=True,
                                                  ctx_layout="mgk")
    )(q, kq, vq, ks, vs, kd, vd, mask).jaxpr
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16, hd=HD, q8=True)


def test_grouped_q8_single_pallas_call_no_dequant_in_hbm():
    """The multi-prefix forest q8 kernel keeps the same guarantee: int8
    segment values + rank-3 scale tensors in, one bf16 output out."""
    q, kq, vq, ks, vs, kd, vd, mask = _bf16_case()
    b = q.shape[0]
    gids = jnp.zeros((b,), jnp.int32)
    clens = jnp.asarray([kq.shape[0]], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: grouped_bifurcated_decode_attention_q8(
            *a, interpret=True, ctx_layout="mgk")
    )(q, kq[None], vq[None], ks[None], vs[None], gids, clens, kd, vd,
      mask).jaxpr
    assert_no_hbm_spill(jaxpr, out_dtype=jnp.bfloat16, hd=HD, q8=True)
