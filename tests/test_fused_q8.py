"""Quantized-context fused decode kernel (kernels/bifurcated_decode.
fused_bifurcated_decode_q8 via ops.bifurcated_decode_attention_q8):

  * interpret-mode sweep vs the einsum q8 reference
    (core.quantized.bifurcated_attention_q8) — the kernel implements the
    same scale-folded math, so agreement is fp32-exactness-tight;
  * quantization-error bound vs the fp32 oracle (monolithic softmax over
    the UNquantized cache): <= 2e-2 relative for int8;
  * structural guarantee: ONE pallas_call whose context operands enter as
    int8 (+ f32 scale vectors) — no dequantized K_c/V_c tensor and no fp32
    partials in HBM;
  * speculative n > 1 rows and ragged / partially-masked decode arms.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bifurcated import bifurcated_attention
from repro.core.quantized import bifurcated_attention_q8, quantize_ctx
from repro.kernels.ops import bifurcated_decode_attention_q8

# (b, p, m_c, c_d, block_m) — m_c values include non-multiples of block_m
# (tail masking in-kernel, scale rows zero-padded alongside the values).
SWEEP = [
    (1, 1, 64, 8, 64),
    (1, 4, 130, 4, 128),     # ragged ctx tail, single sample
    (4, 1, 300, 16, 128),    # ragged tail, mid batch
    (4, 4, 257, 7, 128),     # prime-ish sizes
    (32, 1, 512, 8, 256),    # large batch (paper's regime), aligned ctx
    (32, 4, 96, 24, 128),    # large batch, block_m > m_c
]
G, HD = 2, 32


def make(b, p, m_c, c_d, seed=0, full_mask=False):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, G, p, 1, HD), jnp.float32)
    kc = jnp.asarray(rng.randn(m_c, G, HD), jnp.float32)
    vc = jnp.asarray(rng.randn(m_c, G, HD), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
    if full_mask:
        mask = jnp.ones((b, c_d), bool)
    else:
        # ragged per-sample decode lengths: partially-masked C_d slots
        lens = rng.randint(0, c_d + 1, size=(b,))
        lens[0] = max(1, lens[0])
        mask = jnp.arange(c_d)[None, :] < jnp.asarray(lens)[:, None]
    kq, ks = quantize_ctx(kc, fold_scale=HD**-0.5)  # (m_c, G)
    vq, vs = quantize_ctx(vc)
    return q, kc, vc, kq, vq, ks, vs, kd, vd, mask


def _kernel(q, kq, vq, ks, vs, kd, vd, mask, block_m, ctx_layout="mgk"):
    if ctx_layout == "gmk":
        kq, vq = kq.transpose(1, 0, 2), vq.transpose(1, 0, 2)
        ks, vs = ks.T, vs.T
    return bifurcated_decode_attention_q8(
        q, kq, vq, ks, vs, kd, vd, mask,
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


@pytest.mark.parametrize("shape", SWEEP)
def test_fused_q8_vs_einsum_reference(shape):
    """Same scale-folded math, different execution order: tight agreement."""
    b, p, m_c, c_d, block_m = shape
    q, _, _, kq, vq, ks, vs, kd, vd, mask = make(b, p, m_c, c_d,
                                                 seed=sum(shape))
    out = _kernel(q, kq, vq, ks, vs, kd, vd, mask, block_m)
    ref = bifurcated_attention_q8(q, kq, vq, ks, vs, kd, vd, decode_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SWEEP)
def test_fused_q8_vs_fp32_oracle_quant_bound(shape):
    """Quantization-error bound vs the UNquantized fp32 monolithic softmax:
    <= 2e-2 relative for per-(token, head) int8."""
    b, p, m_c, c_d, block_m = shape
    q, kc, vc, kq, vq, ks, vs, kd, vd, mask = make(b, p, m_c, c_d,
                                                   seed=sum(shape) + 1)
    out = _kernel(q, kq, vq, ks, vs, kd, vd, mask, block_m)
    oracle = bifurcated_attention(q, kc, vc, kd, vd, decode_mask=mask)
    scale = float(jnp.max(jnp.abs(oracle)))
    err = float(jnp.max(jnp.abs(out - oracle)))
    assert err <= 2e-2 * max(scale, 1.0), (err, scale)


def test_fused_q8_gmk_layout_zero_copy_semantics():
    b, p, m_c, c_d = 4, 2, 100, 12
    q, _, _, kq, vq, ks, vs, kd, vd, mask = make(b, p, m_c, c_d, seed=3)
    out_mgk = _kernel(q, kq, vq, ks, vs, kd, vd, mask, 128, "mgk")
    out_gmk = _kernel(q, kq, vq, ks, vs, kd, vd, mask, 128, "gmk")
    np.testing.assert_allclose(np.asarray(out_mgk), np.asarray(out_gmk),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [2, 4])
def test_fused_q8_n_gt_1_speculative_rows(n):
    """Draft-token rows fold into the kernel row dimension like the bf16
    kernel; checked against the einsum q8 reference."""
    b, p, m_c, c_d = 3, 2, 100, 12
    rng = np.random.RandomState(n)
    q = jnp.asarray(rng.randn(b, G, p, n, HD), jnp.float32)
    kc = jnp.asarray(rng.randn(m_c, G, HD), jnp.float32)
    vc = jnp.asarray(rng.randn(m_c, G, HD), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
    mask = jnp.broadcast_to(jnp.arange(c_d)[None] < c_d - 3, (b, c_d))
    kq, ks = quantize_ctx(kc, fold_scale=HD**-0.5)
    vq, vs = quantize_ctx(vc)
    out = bifurcated_decode_attention_q8(q, kq, vq, ks, vs, kd, vd, mask,
                                         interpret=True, ctx_layout="mgk")
    ref = bifurcated_attention_q8(q, kq, vq, ks, vs, kd, vd, decode_mask=mask)
    assert out.shape == (b, G, p, n, HD)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---- structural guarantee: int8 stays int8 all the way into the kernel ----

def _collect_pallas_calls(jaxpr):
    calls = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            calls.append(eqn)
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                calls += _collect_pallas_calls(v.jaxpr)
            elif hasattr(v, "eqns"):
                calls += _collect_pallas_calls(v)
    return calls


def test_fused_q8_single_pallas_call_no_dequant_in_hbm():
    """ONE pallas_call; its context operands are int8 (+ f32 scale VECTORS,
    no hd axis) — i.e. no dequantized (m_c, hd)-shaped float K_c/V_c buffer
    exists anywhere in the jaxpr — and the only output is the normalized
    attention result in the query dtype (no fp32 partials)."""
    b, p, m_c, c_d = 2, 2, 64, 8
    q, _, _, kq, vq, ks, vs, kd, vd, mask = make(b, p, m_c, c_d, seed=1,
                                                 full_mask=True)
    q = q.astype(jnp.bfloat16)
    kd, vd = kd.astype(jnp.bfloat16), vd.astype(jnp.bfloat16)
    jaxpr = jax.make_jaxpr(
        lambda *a: bifurcated_decode_attention_q8(*a, interpret=True,
                                                  ctx_layout="mgk")
    )(q, kq, vq, ks, vs, kd, vd, mask)
    calls = _collect_pallas_calls(jaxpr.jaxpr)
    assert len(calls) == 1, f"expected ONE pallas_call, got {len(calls)}"
    call = calls[0]
    in_avals = [v.aval for v in call.invars]
    assert sum(a.dtype == jnp.int8 for a in in_avals) == 2, in_avals  # K_q, V_q
    # the only FLOAT tensors with a head_dim axis entering the kernel are
    # q and the bf16 decode arm — the context values enter exclusively as
    # int8 (+ rank-2 scale vectors), so no dequantized K_c/V_c buffer is
    # ever an HBM operand
    float_hd = [a for a in in_avals
                if a.dtype != jnp.int8 and a.ndim == 3
                and a.shape[-1] == q.shape[-1]]
    assert len(float_hd) == 3, float_hd            # q, k_dec, v_dec
    outs = call.outvars
    assert len(outs) == 1, f"q8 kernel must write only the output: {outs}"
    assert outs[0].aval.dtype == jnp.bfloat16, outs[0].aval  # no fp32 spills
