"""MoE layer unit tests: routing exactness, capacity behavior, aux loss,
decode-path consistency with the train path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import apply_moe, init_moe, moe_decode

CFG = ModelConfig(
    name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=4,
    n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0, group_size=16),
)


def _params(seed=0):
    return init_moe(CFG, jax.random.PRNGKey(seed))


def test_train_and_decode_paths_agree_with_slack_capacity():
    """With generous capacity (no drops) the dispatch-einsum train path and
    the dense decode path must compute the same function."""
    p = _params()
    x = jnp.asarray(np.random.RandomState(0).randn(2, 16, 32) * 0.5, jnp.float32)
    out_train, _ = apply_moe(CFG, p, x, None)
    out_dec = moe_decode(CFG, p, x, None)
    np.testing.assert_allclose(out_train, out_dec, rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens_when_tight():
    tight = dataclasses.replace(
        CFG, moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=0.3,
                           group_size=16))
    p = _params()
    x = jnp.asarray(np.random.RandomState(1).randn(1, 16, 32), jnp.float32)
    out_tight, _ = apply_moe(tight, p, x, None)
    out_slack, _ = apply_moe(CFG, p, x, None)
    # some tokens dropped -> outputs differ; dropped tokens emit ~0
    assert float(jnp.max(jnp.abs(out_tight - out_slack))) > 1e-4


def test_aux_loss_prefers_balance():
    p = _params()
    # collapse the router to a single expert -> aux loss should exceed the
    # balanced router's
    p_collapsed = dict(p)
    router = np.zeros((32, 4), np.float32)
    router[:, 0] = 10.0
    p_collapsed["router"] = jnp.asarray(router)
    x = jnp.asarray(np.random.RandomState(2).randn(2, 16, 32), jnp.float32)
    _, aux_bal = apply_moe(CFG, p, x, None)
    _, aux_col = apply_moe(CFG, p_collapsed, x, None)
    assert float(aux_col) > float(aux_bal)


def test_gate_weights_normalized():
    """Combine weights renormalize over top-k: scaling router logits by a
    constant shift leaves the output unchanged."""
    p = _params()
    x = jnp.asarray(np.random.RandomState(3).randn(1, 16, 32), jnp.float32)
    out1, _ = apply_moe(CFG, p, x, None)
    p2 = dict(p)
    p2["router"] = p["router"] + 0.0  # same
    out2, _ = apply_moe(CFG, p2, x, None)
    np.testing.assert_allclose(out1, out2, rtol=1e-6)


def test_nonuniform_token_count_padding():
    p = _params()
    x = jnp.asarray(np.random.RandomState(4).randn(3, 28, 32), jnp.float32)
    out, aux = apply_moe(CFG, p, x, None)  # 84 tokens, group 16 -> pad
    assert out.shape == (3, 28, 32)
    assert np.isfinite(float(aux))
