"""Grouped multi-prefix bifurcation (beyond-paper, core/grouped.py):
exactness vs per-group monolithic attention, ragged prefixes, IO dominance."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.attention import multigroup_attention
from repro.core.grouped import (
    grouped_bifurcated_attention,
    grouped_kv_read_bytes,
)


def _ref_one_group(q, kc, vc, kd, vd, ctx_len):
    """Standard attention for one group: broadcast prefix, mask padding."""
    s, g, p, n, k = q.shape
    m_c, m_d = kc.shape[0], kd.shape[1]
    K = jnp.concatenate([jnp.broadcast_to(kc[None], (s, m_c, g, k)), kd], 1)
    V = jnp.concatenate([jnp.broadcast_to(vc[None], (s, m_c, g, k)), vd], 1)
    mask = jnp.concatenate([
        jnp.broadcast_to((jnp.arange(m_c) < ctx_len)[None], (s, m_c)),
        jnp.ones((s, m_d), bool),
    ], axis=1)
    return multigroup_attention(q, K, V, mask=mask[:, None, None, None, :])


@settings(max_examples=15, deadline=None)
@given(
    G=st.integers(1, 4), s=st.integers(1, 4), m_c=st.integers(2, 16),
    m_d=st.integers(1, 6), seed=st.integers(0, 10_000),
)
def test_grouped_matches_per_group_reference(G, s, m_c, m_d, seed):
    rng = np.random.default_rng(seed)
    g, p, n, k = 2, 2, 1, 8
    q = jnp.asarray(rng.standard_normal((G, s, g, p, n, k)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((G, m_c, g, k)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((G, m_c, g, k)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((G, s, m_d, g, k)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((G, s, m_d, g, k)), jnp.float32)
    ctx_lens = jnp.asarray(rng.integers(1, m_c + 1, size=(G,)))

    out = grouped_bifurcated_attention(q, kc, vc, kd, vd,
                                       context_lengths=ctx_lens)
    for gi in range(G):
        ref = _ref_one_group(q[gi], kc[gi], vc[gi], kd[gi], vd[gi],
                             int(ctx_lens[gi]))
        np.testing.assert_allclose(out[gi], ref, rtol=1e-4, atol=1e-4)


def test_grouped_io_model_dominance():
    std = grouped_kv_read_bytes(n_groups=4, samples=16, m_c=8192, m_d=256,
                                g=8, k=128, bifurcated=False)
    bif = grouped_kv_read_bytes(n_groups=4, samples=16, m_c=8192, m_d=256,
                                g=8, k=128, bifurcated=True)
    # per-group s-fold saving survives a mixed batch
    assert std / bif > 10
    # degenerate G=1 reduces to the paper's Eq. 5-6
    from repro.core.io_model import kv_read_bytes

    assert grouped_kv_read_bytes(n_groups=1, samples=8, m_c=100, m_d=10,
                                 g=2, k=8, bifurcated=True) == \
        kv_read_bytes(b=8, m_c=100, m_d=10, g=2, k=8, bifurcated=True)
