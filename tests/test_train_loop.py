"""Integration: fault-tolerant training loop — loss goes down, checkpoints
restart exactly, NaN steps are skipped, gradient compression trains."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, reduced_config
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.runtime.train_loop import run_training

pytestmark = pytest.mark.slow  # CI runs the slow tier in its own step

CFG = reduced_config(get_config("internlm2-1.8b"))
TCFG = TrainConfig(global_batch=8, seq_len=32, learning_rate=2e-3,
                   warmup_steps=5, total_steps=60, checkpoint_every=20,
                   remat="none")


def test_loss_decreases():
    data = SyntheticLMDataset(CFG.vocab_size, 32, seed=0)
    model = get_model(CFG)
    r = run_training(model, CFG, TCFG, data, num_steps=60, log_every=5)
    first = np.mean([l for _, l in r.losses[:2]])
    last = np.mean([l for _, l in r.losses[-2:]])
    assert last < first - 0.2, r.losses


def test_checkpoint_restart_resumes_exactly(tmp_path):
    data = SyntheticLMDataset(CFG.vocab_size, 32, seed=0)
    model = get_model(CFG)
    # uninterrupted run
    r_full = run_training(model, CFG, TCFG, data, num_steps=40,
                          log_every=1, checkpoint_dir=str(tmp_path / "a"))
    # interrupted at 20 + resumed
    run_training(model, CFG, TCFG, data, num_steps=20,
                 log_every=1, checkpoint_dir=str(tmp_path / "b"))
    r_resumed = run_training(model, CFG, TCFG, data, num_steps=40,
                             log_every=1, checkpoint_dir=str(tmp_path / "b"))
    assert r_resumed.resumed_from == 20
    # deterministic data + exact state restore -> identical trailing losses
    tail_full = dict(r_full.losses)[39]
    tail_resumed = dict(r_resumed.losses)[39]
    assert abs(tail_full - tail_resumed) < 5e-3, (tail_full, tail_resumed)


def test_nan_step_skipped_not_fatal():
    model = get_model(CFG)

    class PoisonData:
        def __init__(self):
            self.inner = SyntheticLMDataset(CFG.vocab_size, 32, seed=0)

        def batch(self, step, bs, *a, **k):
            b = self.inner.batch(step, bs)
            if step == 3:  # poison one step via an out-of-range huge mask
                b = dict(b)
                b["mask"] = b["mask"] * np.inf
            return b

    r = run_training(model, CFG, TCFG, PoisonData(), num_steps=6, log_every=1)
    assert r.skipped_steps >= 1
    assert all(np.isfinite(l) or s == 3 for s, l in r.losses)


def test_grad_compression_trains():
    data = SyntheticLMDataset(CFG.vocab_size, 32, seed=0)
    model = get_model(CFG)
    tc = dataclasses.replace(TCFG, grad_compression="int8_ef")
    r = run_training(model, CFG, tc, data, num_steps=50, log_every=5)
    first = np.mean([l for _, l in r.losses[:2]])
    last = np.mean([l for _, l in r.losses[-2:]])
    assert last < first - 0.15, r.losses


def test_step_timeout_raises():
    data = SyntheticLMDataset(CFG.vocab_size, 32, seed=0)
    model = get_model(CFG)
    with pytest.raises(TimeoutError):
        run_training(model, CFG, TCFG, data, num_steps=3,
                     step_timeout_s=1e-9)
