"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle
(ref.py), swept over shapes (MHA/GQA/MQA, ragged m_c, odd head dims) and
dtypes, as the brief requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bifurcated_decode import context_flash_partials
from repro.kernels.ops import bifurcated_decode_attention
from repro.kernels.ref import bifurcated_decode_ref, context_partial_ref

pytestmark = pytest.mark.slow  # CI runs the slow tier in its own step

# (b, g, p, hd, m_c, c_d, block_m)
SWEEP = [
    (2, 2, 2, 16, 64, 8, 32),
    (4, 1, 8, 64, 300, 16, 128),    # MQA, ragged m_c (tail masking)
    (8, 8, 1, 128, 512, 32, 256),   # MHA-ish, aligned
    (1, 2, 2, 80, 130, 4, 128),     # danube-style hd=80, tiny tail block
    (16, 4, 2, 32, 1024, 64, 512),
    (3, 5, 3, 112, 257, 7, 128),    # zamba-style hd=112, prime-ish sizes
]


def make(b, g, p, hd, m_c, c_d, dtype, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, g, p, hd), dtype)
    kc = jnp.asarray(rng.randn(g, m_c, hd), dtype)
    vc = jnp.asarray(rng.randn(g, m_c, hd), dtype)
    kd = jnp.asarray(rng.randn(b, g, c_d, hd), dtype)
    vd = jnp.asarray(rng.randn(b, g, c_d, hd), dtype)
    dec_len = max(1, c_d - 2)
    mask = jnp.broadcast_to(jnp.arange(c_d)[None] < dec_len, (b, c_d))
    return q, kc, vc, kd, vd, mask


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_context_kernel_vs_oracle(shape, dtype):
    b, g, p, hd, m_c, c_d, block_m = shape
    q, kc, vc, *_ = make(b, g, p, hd, m_c, c_d, dtype)
    scale = hd**-0.5
    qk = q.transpose(1, 0, 2, 3).reshape(g, b * p, hd)
    acc, m, l = context_flash_partials(qk, kc, vc, scale=scale,
                                       block_m=block_m, interpret=True)
    # oracle works in (b, g, p, ...) layout with (g, m, hd) context
    acc_r, m_r, l_r = context_partial_ref(q, kc, vc, scale)
    acc_r2 = acc_r.transpose(1, 0, 2, 3).reshape(g, b * p, hd)
    m_r2 = m_r.transpose(1, 0, 2).reshape(g, b * p)
    l_r2 = l_r.transpose(1, 0, 2).reshape(g, b * p)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(m, m_r2, rtol=tol, atol=tol)
    np.testing.assert_allclose(l, l_r2, rtol=tol * 4, atol=tol * 4)
    np.testing.assert_allclose(acc, acc_r2, rtol=tol * 8, atol=tol * 8)


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_op_vs_oracle(shape, dtype):
    b, g, p, hd, m_c, c_d, block_m = shape
    q, kc, vc, kd, vd, mask = make(b, g, p, hd, m_c, c_d, dtype)
    out = bifurcated_decode_attention(
        q[:, :, :, None, :],
        kc.transpose(1, 0, 2),  # cache layout (m_c, g, hd)
        vc.transpose(1, 0, 2),
        kd.transpose(0, 2, 1, 3),  # cache layout (b, c_d, g, hd)
        vd.transpose(0, 2, 1, 3),
        mask, block_m=block_m, interpret=True,
    )[:, :, :, 0, :]
    ref = bifurcated_decode_ref(q, kc, vc, kd, vd, mask, hd**-0.5)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_fused_op_matches_model_einsum_path():
    """Kernel path == core.bifurcated_attention (the paper-faithful path)."""
    from repro.core import bifurcated_attention

    b, g, p, hd, m_c, c_d = 4, 2, 2, 32, 100, 12
    q, kc, vc, kd, vd, mask = make(b, g, p, hd, m_c, c_d, jnp.float32)
    out_k = bifurcated_decode_attention(
        q[:, :, :, None, :], kc.transpose(1, 0, 2), vc.transpose(1, 0, 2),
        kd.transpose(0, 2, 1, 3), vd.transpose(0, 2, 1, 3), mask,
        interpret=True)
    out_e = bifurcated_attention(
        q[:, :, :, None, :], kc.transpose(1, 0, 2), vc.transpose(1, 0, 2),
        kd.transpose(0, 2, 1, 3), vd.transpose(0, 2, 1, 3),
        decode_mask=mask)
    np.testing.assert_allclose(out_k, out_e, rtol=3e-5, atol=3e-5)


# ---- flash prefill kernel (kernels/flash_prefill.py) ----

PREFILL_SWEEP = [
    # (b, n, m, h, g, hd, block_q, block_k, causal, window)
    (1, 64, 64, 4, 2, 16, 16, 16, True, 0),
    (2, 100, 100, 4, 4, 32, 32, 16, True, 0),     # MHA, ragged
    (2, 128, 128, 8, 1, 64, 64, 64, True, 0),     # MQA
    (1, 96, 96, 4, 2, 16, 32, 32, True, 20),      # SWA
    (2, 80, 80, 2, 2, 80, 16, 16, False, 0),      # encoder (bidir), hd=80
]


@pytest.mark.parametrize("case", PREFILL_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_vs_oracle(case, dtype):
    from repro.kernels.flash_prefill import flash_prefill_attention
    from repro.models.blocks import chunked_attention

    b, n, m, h, g, hd, bq, bk, causal, window = case
    rng = np.random.RandomState(sum(case))
    q = jnp.asarray(rng.randn(b, n, h, hd), dtype)
    k = jnp.asarray(rng.randn(b, m, g, hd), dtype)
    v = jnp.asarray(rng.randn(b, m, g, hd), dtype)
    out = flash_prefill_attention(q, k, v, causal=causal,
                                  window=window, block_q=bq, block_k=bk,
                                  interpret=True)
    ref = chunked_attention(q, k, v, causal=causal,
                            window=(window or None), chunk=32)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ---- chunked linear attention kernel (kernels/chunked_linear.py) ----

CHUNK_SWEEP = [
    # (b, n, H, dk, dv, chunk, normalize)
    (2, 50, 3, 8, 8, 16, False),
    (1, 64, 2, 16, 16, 16, True),    # mLSTM-style with normalizer
    (2, 100, 4, 32, 16, 32, False),  # Mamba2-style, dk != dv
    (3, 33, 1, 8, 8, 8, True),       # ragged n
]


@pytest.mark.parametrize("case", CHUNK_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_linear_kernel_vs_oracle(case, dtype):
    from repro.kernels.chunked_linear import chunked_linear_attention_kernel
    from repro.models.linear_scan import reference_linear_attention

    b, n, H, dk, dv, chunk, normalize = case
    rng = np.random.RandomState(sum(case))
    q = jnp.asarray(rng.randn(b, n, H, dk), dtype)
    k = jnp.asarray(rng.randn(b, n, H, dk), dtype)
    v = jnp.asarray(rng.randn(b, n, H, dv), dtype)
    a = jnp.asarray(-np.abs(rng.randn(b, n, H)) * 0.3, jnp.float32)
    out, state = chunked_linear_attention_kernel(
        q, k, v, a, chunk=chunk, normalize=normalize, interpret=True)
    out_r, state_r = reference_linear_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        a, normalize=normalize)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)
    if not normalize:
        np.testing.assert_allclose(np.asarray(state), np.asarray(state_r),
                                   rtol=tol * 2, atol=tol * 2)
