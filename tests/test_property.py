"""Property-based tests (hypothesis) on the system's core invariants:

  P1  bifurcated == standard attention for ANY (b, g, p, m_c, m_d) split;
  P2  attention output is invariant to WHERE the context/decode boundary
      is drawn (pure refactoring of the same softmax);
  P3  partial-softmax merge is associative/order-invariant (what makes
      sequence-sharded K_c exact);
  P4  chunked linear attention == sequential recurrence for any chunk size;
  P5  KV-IO model: bifurcated bytes <= standard bytes, equality iff b == 1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: property tests only
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bifurcated_attention, multigroup_attention
from repro.core.bifurcated import _partial_softmax, merge_partials
from repro.core.io_model import kv_read_bytes
from repro.models.linear_scan import (
    chunked_linear_attention,
    reference_linear_attention,
)

SETTINGS = dict(max_examples=20, deadline=None)


def _mk(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 5), g=st.integers(1, 3), p=st.integers(1, 3),
    m_c=st.integers(1, 24), m_d=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_p1_bifurcated_equals_standard(b, g, p, m_c, m_d, seed):
    rng = np.random.default_rng(seed)
    k = 8
    q = _mk(rng, b, g, p, 1, k)
    kc, vc = _mk(rng, m_c, g, k), _mk(rng, m_c, g, k)
    kd, vd = _mk(rng, b, m_d, g, k), _mk(rng, b, m_d, g, k)
    out = bifurcated_attention(q, kc, vc, kd, vd)
    K = jnp.concatenate([jnp.broadcast_to(kc[None], (b, m_c, g, k)), kd], 1)
    V = jnp.concatenate([jnp.broadcast_to(vc[None], (b, m_c, g, k)), vd], 1)
    ref = multigroup_attention(q, K, V)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m_total=st.integers(4, 32), split=st.floats(0.1, 0.9),
    seed=st.integers(0, 10_000),
)
def test_p2_boundary_invariance(m_total, split, seed):
    """Moving the context/decode boundary never changes the result."""
    rng = np.random.default_rng(seed)
    b, g, p, k = 3, 2, 2, 8
    q = _mk(rng, b, g, p, 1, k)
    K = _mk(rng, m_total, g, k)
    V = _mk(rng, m_total, g, k)
    outs = []
    for frac in (split, 0.5):
        m_c = max(1, min(m_total - 1, int(m_total * frac)))
        kc, kd = K[:m_c], jnp.broadcast_to(K[m_c:][None], (b, m_total - m_c, g, k))
        vc, vd = V[:m_c], jnp.broadcast_to(V[m_c:][None], (b, m_total - m_c, g, k))
        outs.append(bifurcated_attention(q, kc, vc, kd, vd))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    n_shards=st.integers(1, 5), m_per=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_p3_partial_merge_shard_invariance(n_shards, m_per, seed):
    rng = np.random.default_rng(seed)
    b, g, p, k = 2, 2, 1, 8
    m = n_shards * m_per
    q = _mk(rng, b, g, p, 1, k)
    K, V = _mk(rng, m, g, k), _mk(rng, m, g, k)
    scale = k**-0.5
    logits = jnp.einsum("bgpnk,mgk->bgpnm", q, K) * scale
    parts = [
        _partial_softmax(logits[..., i * m_per:(i + 1) * m_per],
                         V[i * m_per:(i + 1) * m_per], batched=False)
        for i in range(n_shards)
    ]
    merged = merge_partials(parts)
    mono = merge_partials([_partial_softmax(logits, V, batched=False)])
    np.testing.assert_allclose(merged, mono, rtol=1e-4, atol=1e-4)
    # order invariance (psum semantics)
    merged_rev = merge_partials(parts[::-1])
    np.testing.assert_allclose(merged, merged_rev, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(2, 40), chunk=st.integers(1, 16),
    normalize=st.booleans(), seed=st.integers(0, 10_000),
)
def test_p4_chunked_scan_equals_recurrence(n, chunk, normalize, seed):
    rng = np.random.default_rng(seed)
    b, H, dk, dv = 2, 2, 4, 4
    q, k = _mk(rng, b, n, H, dk), _mk(rng, b, n, H, dk)
    v = _mk(rng, b, n, H, dv)
    a = -jnp.abs(_mk(rng, b, n, H)) * 0.3
    out_c, S_c = chunked_linear_attention(q, k, v, a, chunk=chunk,
                                          normalize=normalize)
    out_r, S_r = reference_linear_attention(q, k, v, a, normalize=normalize)
    np.testing.assert_allclose(out_c, out_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S_c, S_r, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 64), m_c=st.integers(1, 10_000), m_d=st.integers(0, 512),
    g=st.integers(1, 64), k=st.sampled_from([64, 80, 112, 128]),
)
def test_p5_io_model_dominance(b, m_c, m_d, g, k):
    std = kv_read_bytes(b=b, m_c=m_c, m_d=m_d, g=g, k=k, bifurcated=False)
    bif = kv_read_bytes(b=b, m_c=m_c, m_d=m_d, g=g, k=k, bifurcated=True)
    assert bif <= std
    if b == 1:
        assert bif == std
    if b > 1 and m_c > 0:
        assert bif < std
