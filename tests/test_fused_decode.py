"""Single-pass fused bifurcated decode kernel (kernels/bifurcated_decode.
fused_bifurcated_decode via ops.bifurcated_decode_attention):

  * interpret-mode exactness vs the monolithic-softmax oracle (ref.py) over
    b x p x tail x mask x dtype sweeps (acceptance: <= 1e-5 f32, 2e-2 bf16);
  * structural guarantee: ONE pallas_call, ONE output, no fp32 acc/m/l
    partials in its out_shape;
  * n > 1 (speculative draft tokens) folded into the kernel row dimension,
    checked against core.bifurcated_attention;
  * fused == two_pass escape hatch on identical inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bifurcated import bifurcated_attention
from repro.kernels.ops import bifurcated_decode_attention
from repro.kernels.ref import bifurcated_decode_ref

# (b, p, m_c, c_d, block_m) — g/hd fixed small to keep interpret mode fast;
# m_c values include non-multiples of block_m (tail masking in-kernel).
SWEEP = [
    (1, 1, 64, 8, 64),
    (1, 4, 130, 4, 128),     # ragged ctx tail, single sample
    (4, 1, 300, 16, 128),    # ragged tail, mid batch
    (4, 4, 257, 7, 128),     # prime-ish sizes
    (32, 1, 512, 8, 256),    # large batch (paper's regime), aligned ctx
    (32, 4, 96, 24, 128),    # large batch, block_m > m_c
]
G, HD = 2, 32


def make(b, p, m_c, c_d, dtype, seed=0, full_mask=False):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, G, p, HD), dtype)
    kc = jnp.asarray(rng.randn(G, m_c, HD), dtype)
    vc = jnp.asarray(rng.randn(G, m_c, HD), dtype)
    kd = jnp.asarray(rng.randn(b, G, c_d, HD), dtype)
    vd = jnp.asarray(rng.randn(b, G, c_d, HD), dtype)
    if full_mask:
        mask = jnp.ones((b, c_d), bool)
    else:
        # ragged per-sample decode lengths: partially-masked C_d slots
        lens = rng.randint(0, c_d + 1, size=(b,))
        lens[0] = max(1, lens[0])
        mask = jnp.arange(c_d)[None, :] < jnp.asarray(lens)[:, None]
    return q, kc, vc, kd, vd, mask


def _fused(q, kc, vc, kd, vd, mask, block_m, **kw):
    """Call through ops with framework ("mgk"/batch-major) cache layouts."""
    return bifurcated_decode_attention(
        q[:, :, :, None, :], kc.transpose(1, 0, 2), vc.transpose(1, 0, 2),
        kd.transpose(0, 2, 1, 3), vd.transpose(0, 2, 1, 3), mask,
        block_m=block_m, interpret=True, **kw)[:, :, :, 0, :]


@pytest.mark.parametrize("shape", SWEEP)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
def test_fused_vs_oracle(shape, dtype, tol):
    b, p, m_c, c_d, block_m = shape
    q, kc, vc, kd, vd, mask = make(b, p, m_c, c_d, dtype, seed=sum(shape))
    out = _fused(q, kc, vc, kd, vd, mask, block_m)
    ref = bifurcated_decode_ref(q, kc, vc, kd, vd, mask, HD**-0.5)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SWEEP[:3])
def test_fused_matches_two_pass(shape):
    b, p, m_c, c_d, block_m = shape
    q, kc, vc, kd, vd, mask = make(b, p, m_c, c_d, jnp.float32, seed=7)
    out_f = _fused(q, kc, vc, kd, vd, mask, block_m)
    out_t = _fused(q, kc, vc, kd, vd, mask, block_m, two_pass=True)
    np.testing.assert_allclose(out_f, out_t, rtol=1e-5, atol=1e-5)


def test_fused_gmk_layout_zero_copy_semantics():
    """"gmk" (head-major) context input produces identical results."""
    b, p, m_c, c_d = 4, 2, 100, 12
    q, kc, vc, kd, vd, mask = make(b, p, m_c, c_d, jnp.float32, seed=3)
    out_mgk = _fused(q, kc, vc, kd, vd, mask, 128)
    out_gmk = bifurcated_decode_attention(
        q[:, :, :, None, :], kc, vc,  # already (g, m_c, hd)
        kd.transpose(0, 2, 1, 3), vd.transpose(0, 2, 1, 3), mask,
        block_m=128, interpret=True, ctx_layout="gmk")[:, :, :, 0, :]
    np.testing.assert_allclose(out_mgk, out_gmk, rtol=1e-6, atol=1e-6)


# ---- structural guarantee: one pallas_call, normalized single output ----

def _collect_pallas_calls(jaxpr):
    calls = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            calls.append(eqn)
        for v in eqn.params.values():
            # duck-typed: ClosedJaxpr (has .jaxpr) / raw Jaxpr (has .eqns)
            # moved modules across jax versions
            if hasattr(v, "jaxpr"):
                calls += _collect_pallas_calls(v.jaxpr)
            elif hasattr(v, "eqns"):
                calls += _collect_pallas_calls(v)
    return calls


def _pallas_calls_of(two_pass):
    b, p, m_c, c_d = 2, 2, 64, 8
    q, kc, vc, kd, vd, mask = make(b, p, m_c, c_d, jnp.bfloat16, seed=1,
                                   full_mask=True)
    jaxpr = jax.make_jaxpr(
        lambda *a: bifurcated_decode_attention(*a, interpret=True,
                                               two_pass=two_pass)
    )(q[:, :, :, None, :], kc.transpose(1, 0, 2), vc.transpose(1, 0, 2),
      kd.transpose(0, 2, 1, 3), vd.transpose(0, 2, 1, 3), mask)
    return _collect_pallas_calls(jaxpr.jaxpr)


def test_fused_is_single_pallas_call_no_partial_outputs():
    calls = _pallas_calls_of(two_pass=False)
    assert len(calls) == 1, f"expected ONE pallas_call, got {len(calls)}"
    outs = calls[0].outvars
    assert len(outs) == 1, f"fused kernel must write only the output: {outs}"
    # normalized output in the query dtype — no fp32 acc/m/l spills
    assert outs[0].aval.dtype == jnp.bfloat16, outs[0].aval


def test_two_pass_spills_fp32_partials():
    """The escape hatch keeps the historical 3-output partials kernel."""
    calls = _pallas_calls_of(two_pass=True)
    assert len(calls) == 1
    outs = calls[0].outvars
    assert len(outs) == 3  # acc, m, l
    assert all(o.aval.dtype == jnp.float32 for o in outs)


# ---- speculative n > 1 (satellite: n folded into kernel rows) ----

@pytest.mark.parametrize("two_pass", [False, True])
@pytest.mark.parametrize("n", [2, 4])
def test_n_gt_1_matches_bifurcated_attention(two_pass, n):
    b, g, p, hd, m_c, c_d = 3, 2, 2, 32, 100, 12
    rng = np.random.RandomState(n)
    q = jnp.asarray(rng.randn(b, g, p, n, hd), jnp.float32)
    kc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    vc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    mask = jnp.broadcast_to(jnp.arange(c_d)[None] < c_d - 3, (b, c_d))
    out = bifurcated_decode_attention(q, kc, vc, kd, vd, mask,
                                      interpret=True, two_pass=two_pass)
    ref = bifurcated_attention(q, kc, vc, kd, vd, decode_mask=mask)
    assert out.shape == (b, g, p, n, hd)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_n_gt_1_through_model_kernel_impl():
    """decode_step(impl="kernel") accepts n>1 draft blocks end-to-end."""
    from repro.configs import get_config, reduced_config
    from repro.core.kv_cache import BifurcatedCache
    from repro.models import get_model

    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 24)))
    _, c1 = model.prefill(params, ctx, None)
    b, n_g = 3, 4
    cache = BifurcatedCache.from_prefill(c1.k[:, 0], c1.v[:, 0], b, 16,
                                         dtype=c1.k.dtype,
                                         ctx_layout=cfg.ctx_layout)
    draft = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, n_g)))
    lk, _ = model.decode_step(params, cache, draft, None, impl="kernel")
    le, _ = model.decode_step(params, cache, draft, None, impl="einsum")
    assert lk.shape == (b, n_g, cfg.padded_vocab)
    assert not bool(jnp.isnan(lk).any())
    scale = float(jnp.max(jnp.abs(le)))
    assert float(jnp.max(jnp.abs(lk - le))) < 0.05 * max(scale, 1.0)
