"""Single-pass fused bifurcated decode kernel — kernel-specific guarantees.

Exactness sweeps vs the fp32 oracle / the other implementations moved to
the differential harness (tests/test_differential.py), which runs every
impl on identical inputs from tests/conftest.make_decode_case. This file
keeps what is specific to the FUSED kernel:

  * structural no-HBM-spill guarantee (conftest.assert_no_hbm_spill): ONE
    pallas_call, one normalized output in the query dtype — vs the two-pass
    escape hatch, which spills the historical fp32 partials;
  * n > 1 (speculative draft tokens) through the MODEL's decode_step;
  * the fused == two_pass merge identity on one canonical case.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_no_hbm_spill, collect_pallas_calls, make_decode_case
from repro.core.bifurcated import bifurcated_attention
from repro.kernels.ops import bifurcated_decode_attention

G, HD = 2, 32


def _fused(case, block_m, **kw):
    return bifurcated_decode_attention(
        case["q"], case["kc"], case["vc"], case["kd"], case["vd"],
        case["mask"], block_m=block_m, interpret=True, **kw)


def test_fused_matches_two_pass():
    case = make_decode_case(4, 2, 300, 16, g=G, hd=HD, seed=7)
    out_f = _fused(case, 128)
    out_t = _fused(case, 128, two_pass=True)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_t),
                               rtol=1e-5, atol=1e-5)


# ---- structural guarantee: one pallas_call, normalized single output ----

def _jaxpr_of(two_pass):
    case = make_decode_case(2, 2, 64, 8, g=G, hd=HD, dtype=jnp.bfloat16,
                            seed=1, full_mask=True)
    return jax.make_jaxpr(
        lambda *a: bifurcated_decode_attention(*a, interpret=True,
                                               two_pass=two_pass)
    )(case["q"], case["kc"], case["vc"], case["kd"], case["vd"],
      case["mask"]).jaxpr


def test_fused_is_single_pallas_call_no_partial_outputs():
    assert_no_hbm_spill(_jaxpr_of(two_pass=False), out_dtype=jnp.bfloat16)


def test_two_pass_spills_fp32_partials():
    """The escape hatch keeps the historical 3-output partials kernel."""
    calls = collect_pallas_calls(_jaxpr_of(two_pass=True))
    assert len(calls) == 1
    outs = calls[0].outvars
    assert len(outs) == 3  # acc, m, l
    assert all(o.aval.dtype == jnp.float32 for o in outs)


# ---- speculative n > 1 (satellite: n folded into kernel rows) ----

@pytest.mark.parametrize("two_pass", [False, True])
@pytest.mark.parametrize("n", [2, 4])
def test_n_gt_1_matches_bifurcated_attention(two_pass, n):
    case = make_decode_case(3, 2, 100, 12, g=G, hd=HD, n=n, seed=n)
    out = _fused(case, 512, two_pass=two_pass)
    ref = bifurcated_attention(case["q"], case["kc"], case["vc"],
                               case["kd"], case["vd"],
                               decode_mask=case["mask"])
    assert out.shape == case["q"].shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_n_gt_1_through_model_kernel_impl():
    """decode_step(impl="kernel") accepts n>1 draft blocks end-to-end."""
    from repro.configs import get_config, reduced_config
    from repro.core.kv_cache import BifurcatedCache
    from repro.models import get_model

    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 24)))
    _, c1 = model.prefill(params, ctx, None)
    b, n_g = 3, 4
    cache = BifurcatedCache.from_prefill(c1.k[:, 0], c1.v[:, 0], b, 16,
                                         dtype=c1.k.dtype,
                                         ctx_layout=cfg.ctx_layout)
    draft = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, n_g)))
    lk, _ = model.decode_step(params, cache, draft, None, impl="kernel")
    le, _ = model.decode_step(params, cache, draft, None, impl="einsum")
    assert lk.shape == (b, n_g, cfg.padded_vocab)
    assert not bool(jnp.isnan(lk).any())
    scale = float(jnp.max(jnp.abs(le)))
    assert float(jnp.max(jnp.abs(lk - le))) < 0.05 * max(scale, 1.0)
