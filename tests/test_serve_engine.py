"""Serve-engine integration: bifurcated vs standard produce identical
samples, policy switch behavior, reranking, kernel path, spec-decode n>1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ServeConfig, get_config, reduced_config
from repro.core import BifurcatedCache
from repro.models import get_model
from repro.runtime.serve import ServeEngine, rank_by_mean_logprob, sample_tokens

pytestmark = pytest.mark.slow  # CI runs the slow tier in its own step

CFG = reduced_config(get_config("internlm2-1.8b"))
MODEL = get_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
CTX = jnp.asarray(np.random.RandomState(0).randint(0, CFG.vocab_size, (1, 48)))


def _engine(bifurcated, use_kernel=False, batch=6, cache_dtype="bfloat16",
            temperature=0.8):
    from repro.core.policy import BifurcationPolicy

    scfg = ServeConfig(batch=batch, decode_capacity=16, temperature=temperature,
                       top_p=0.95, bifurcated=bifurcated, use_kernel=use_kernel,
                       cache_dtype=cache_dtype)
    # reduced configs sit below the production IO threshold; force the
    # requested mode so tests exercise the real bifurcated path
    policy = BifurcationPolicy(enabled=bifurcated, min_io_saving_bytes=0)
    return ServeEngine(MODEL, CFG, scfg, policy=policy)


def test_bifurcated_and_standard_sample_nearly_identically():
    """Math-level exactness is proven in fp32 (tests/test_bifurcated.py);
    in bf16 the split-sum reduction order can flip near-tied samples, so the
    end-to-end check asserts high token agreement, not bit identity."""
    r_b = _engine(True).generate(PARAMS, CTX, n_steps=8,
                                 key=jax.random.PRNGKey(3))
    r_s = _engine(False).generate(PARAMS, CTX, n_steps=8,
                                  key=jax.random.PRNGKey(3))
    agree = float(np.mean(np.asarray(r_b.tokens) == np.asarray(r_s.tokens)))
    assert agree >= 0.85, agree
    np.testing.assert_allclose(np.asarray(r_b.mean_logprob),
                               np.asarray(r_s.mean_logprob), rtol=0.2, atol=0.2)


def test_kernel_path_matches_einsum_path():
    r_k = _engine(True, use_kernel=True).generate(
        PARAMS, CTX, n_steps=6, key=jax.random.PRNGKey(5))
    r_e = _engine(True, use_kernel=False).generate(
        PARAMS, CTX, n_steps=6, key=jax.random.PRNGKey(5))
    agree = float(np.mean(np.asarray(r_k.tokens) == np.asarray(r_e.tokens)))
    assert agree >= 0.85, agree  # bf16 merge-order tolerance, see above


def test_policy_falls_back_for_tiny_workloads():
    eng = ServeEngine(MODEL, CFG, ServeConfig(batch=1, bifurcated=True))
    assert not eng.should_bifurcate(1, 8192)      # batch 1: never
    assert not eng.should_bifurcate(2, 4)          # tiny context
    big = ServeConfig(batch=16, bifurcated=True)
    eng2 = ServeEngine(MODEL, CFG, big)
    assert eng2.should_bifurcate(16, 4096)


def test_cache_memory_footprint_single_context():
    """Bifurcated cache stores the context ONCE: m_c + b*C_d slots, vs the
    standard cache's b*(m_c + C_d) — the paper's §5.2.2 capacity win."""
    b, m_c, cd = 16, 48, 16
    _, cache = _engine(True, batch=b).prefill_shared(PARAMS, CTX, b)
    assert isinstance(cache, BifurcatedCache)
    slots_bif = cache.context_len + b * cache.decode_capacity
    _, std = _engine(False, batch=b).prefill_shared(PARAMS, CTX, b)
    slots_std = b * std.k.shape[2]
    assert slots_bif < slots_std / 3


def test_rerank_dedups_and_orders():
    class R:  # minimal GenerationResult stand-in
        tokens = jnp.asarray([[1, 2], [1, 2], [3, 4], [5, 6]])
        mean_logprob = jnp.asarray([-1.0, -1.0, -0.5, -2.0])

    order = rank_by_mean_logprob(R(), top_k=3)
    assert order[0] == 2            # best score first
    assert len(order) == 3          # duplicate row dropped
    assert set(order) == {2, 0, 3} or set(order) == {2, 1, 3}


def test_rerank_ties_break_by_sample_index():
    """Equal-score samples rank in submission order (stable sort), and only
    the best-ranked occurrence of a duplicate row survives."""
    class R:
        tokens = jnp.asarray([[9, 9], [1, 2], [1, 2], [3, 4]])
        mean_logprob = jnp.asarray([-1.0, -1.0, -1.0, -1.0])

    order = rank_by_mean_logprob(R(), top_k=4)
    assert order == [0, 1, 3]        # all tied: index order, dup row 2 gone


def test_rerank_empty_steps():
    """Zero generated tokens (n_steps=0 shapes): every row is the same
    empty sequence — one representative survives, ranked by score."""
    class R:
        tokens = jnp.zeros((3, 0), jnp.int32)
        mean_logprob = jnp.asarray([-2.0, -0.5, -1.0])

    order = rank_by_mean_logprob(R(), top_k=3)
    assert order == [1]


def test_should_bifurcate_threshold_boundaries():
    """The policy switch is exact at its boundaries: savings straddling
    min_io_saving_bytes and batches straddling min_batch flip the decision
    (paper FAQ #4 made precise)."""
    from repro.core.policy import BifurcationPolicy

    pol = BifurcationPolicy(enabled=True, min_batch=2,
                            min_io_saving_bytes=1 << 20)
    kw = dict(n_groups=8, head_dim=128, bytes_per_el=2)
    # saving = 2*g*k*m_c*(b-1)*bytes: solve m_c for EXACTLY 1 MiB at b=2
    m_exact = (1 << 20) // (2 * 8 * 128 * 1 * 2)
    assert pol.io_saving_bytes(batch=2, m_c=m_exact, **kw) == 1 << 20
    assert pol.should_bifurcate(batch=2, m_c=m_exact, **kw)         # ==
    assert not pol.should_bifurcate(batch=2, m_c=m_exact - 1, **kw)  # 1 below
    assert pol.should_bifurcate(batch=2, m_c=m_exact + 1, **kw)      # 1 above
    # batch boundary: min_batch is inclusive, below it never bifurcates
    assert not pol.should_bifurcate(batch=1, m_c=1 << 20, **kw)
    assert pol.should_bifurcate(batch=2, m_c=1 << 20, **kw)
    # disabled policy rejects even the paper's sweet spot
    off = BifurcationPolicy(enabled=False, min_io_saving_bytes=0)
    assert not off.should_bifurcate(batch=32, m_c=1 << 20, **kw)


def test_sample_tokens_greedy_and_topp():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample_tokens(jax.random.PRNGKey(0), logits, 0.0, 1.0)[0]) == 1
    # top-p keeps the head of the distribution only
    toks = [int(sample_tokens(jax.random.PRNGKey(i), logits, 1.0, 0.5)[0])
            for i in range(20)]
    assert set(toks) == {1}


def test_scan_loop_matches_python_loop():
    """The single-dispatch lax.scan decode phase reproduces the per-token
    python loop EXACTLY (same RNG stream => identical tokens/logprobs)."""
    r_scan = _engine(True).generate(PARAMS, CTX, n_steps=8,
                                    key=jax.random.PRNGKey(11), loop="scan")
    r_loop = _engine(True).generate(PARAMS, CTX, n_steps=8,
                                    key=jax.random.PRNGKey(11), loop="python")
    np.testing.assert_array_equal(np.asarray(r_scan.tokens),
                                  np.asarray(r_loop.tokens))
    np.testing.assert_allclose(np.asarray(r_scan.logprobs),
                               np.asarray(r_loop.logprobs),
                               rtol=1e-5, atol=1e-5)


def test_decode_phase_is_one_dispatch_one_compile():
    """Acceptance: the decode phase of generate() is exactly ONE jitted
    dispatch (lax.scan), and repeated same-shape generations hit the same
    executable (compile count stays 1)."""
    eng = _engine(True)
    assert eng.decode_dispatches == 0
    eng.generate(PARAMS, CTX, n_steps=8, key=jax.random.PRNGKey(0))
    assert eng.decode_dispatches == 1
    eng.generate(PARAMS, CTX, n_steps=8, key=jax.random.PRNGKey(1))
    assert eng.decode_dispatches == 2          # one dispatch per generate
    assert eng._decode_scan._cache_size() == 1  # ... but a single compile
    # the python loop pays one dispatch per token instead
    eng2 = _engine(True)
    eng2.generate(PARAMS, CTX, n_steps=8, key=jax.random.PRNGKey(0),
                  loop="python")
    assert eng2.decode_dispatches == 7


def test_int8_cache_greedy_matches_bf16():
    """Acceptance: ServeEngine(cache_dtype="int8") decodes through the SAME
    jitted lax.scan dispatch (donated quantized carry) and greedy (argmax)
    tokens are identical to the bf16 cache on a small model."""
    from repro.core.quantized import QuantBifurcatedCache

    eng_q8 = _engine(True, cache_dtype="int8", temperature=0.0)
    eng_fp = _engine(True, temperature=0.0)
    _, cache = eng_q8.prefill_shared(PARAMS, CTX, 6)
    assert isinstance(cache, QuantBifurcatedCache)
    assert cache.k_ctx.dtype == jnp.int8
    r_q8 = eng_q8.generate(PARAMS, CTX, n_steps=8, key=jax.random.PRNGKey(9))
    r_fp = eng_fp.generate(PARAMS, CTX, n_steps=8, key=jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(r_q8.tokens),
                                  np.asarray(r_fp.tokens))
    # int8 path is still one fused decode dispatch (scan), not per-token
    assert eng_q8.decode_dispatches == 1


def test_int8_cache_scan_matches_python_loop():
    """The donated quantized carry survives the lax.scan round trip: same
    tokens as the per-token python dispatch loop."""
    r_scan = _engine(True, cache_dtype="int8").generate(
        PARAMS, CTX, n_steps=6, key=jax.random.PRNGKey(13), loop="scan")
    r_loop = _engine(True, cache_dtype="int8").generate(
        PARAMS, CTX, n_steps=6, key=jax.random.PRNGKey(13), loop="python")
    np.testing.assert_array_equal(np.asarray(r_scan.tokens),
                                  np.asarray(r_loop.tokens))


def test_speculative_n_tokens_decode():
    """Paper §G: bifurcation persists under multi-token (draft) decoding."""
    from repro.core.kv_cache import BifurcatedCache

    _, cache1 = MODEL.prefill(PARAMS, CTX, None)
    b, n_g = 3, 4
    cache = BifurcatedCache.from_prefill(cache1.k[:, 0], cache1.v[:, 0], b, 16,
                                         dtype=cache1.k.dtype)
    draft = jnp.asarray(np.random.RandomState(2).randint(
        0, CFG.vocab_size, (b, n_g)))
    logits, cache2 = MODEL.decode_step(PARAMS, cache, draft, None)
    assert logits.shape == (b, n_g, CFG.padded_vocab)
    assert int(cache2.dec_length) == n_g
    assert not bool(jnp.isnan(logits).any())
