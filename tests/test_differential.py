"""Differential test harness for the bifurcated-decode implementation stack.

ONE parametrized harness runs every implementation — {fused, fused_q8,
two_pass, einsum, einsum_q8, grouped, grouped_q8, tree, tree_q8, paged,
paged_q8, packed, packed_q8} — on IDENTICAL inputs
(tests/conftest.make_decode_case) and cross-checks:

  * every implementation against the fp32 monolithic-softmax oracle
    (standard attention over [broadcast K_c ⊕ K_d]) with per-dtype /
    per-quantization tolerances;
  * every PAIR of implementations against each other (catching agreeing-
    but-wrong regressions the oracle check alone can miss), with the pair
    tolerance = max of the two members';
  * the q8 pair (fused_q8 vs einsum_q8) at fp32 tightness — same
    scale-folded math, different execution order;
  * the grouped (multi-prefix forest) kernel at G == 1 BIT-IDENTICAL to
    the single-prefix fused kernel — PR 3's reduction acceptance;
  * the tree (hierarchical cascade) kernel at L=2 (flat forest config)
    BIT-IDENTICAL to the grouped kernel and at L=1 (single prefix) to the
    fused kernel — PR 4's reduction acceptance (multi-level trie
    correctness lives in tests/test_tree.py);
  * the paged page-walk kernel (page-pool storage, SHUFFLED pool pages)
    BIT-IDENTICAL to the dense tree kernel at page_m == block_m — PR 5's
    reduction acceptance (paged structure/engines live in
    tests/test_paged.py) — plus a hypothesis fuzz over page-table
    permutations and ragged node lengths;
  * the packed work-queue kernel on a DECODE-ONLY queue BIT-IDENTICAL to
    the paged kernel, single- and multi-launch — the packed-step
    reduction acceptance (chunk-carrying queues live in
    tests/test_packed.py).

The case list sweeps b x p x n x ragged m_c x partial C_d masks x both ctx
layouts x {f32, bf16}. When ``hypothesis`` is installed (CI installs it; a
fixed-seed derandomized profile is registered in conftest.py) an additional
fuzz pass generates adversarial shapes/seeds on top of the fixed grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_decode_case
from repro.core.attention import decode_attention
from repro.core.bifurcated import bifurcated_attention
from repro.core.quantized import bifurcated_attention_q8, quantize_ctx
from repro.kernels.ops import (
    bifurcated_decode_attention,
    bifurcated_decode_attention_q8,
    grouped_bifurcated_decode_attention,
    grouped_bifurcated_decode_attention_q8,
    packed_bifurcated_decode_attention,
    packed_bifurcated_decode_attention_q8,
    paged_bifurcated_decode_attention,
    paged_bifurcated_decode_attention_q8,
    tree_bifurcated_decode_attention,
    tree_bifurcated_decode_attention_q8,
)

G, HD = 2, 32


# ---------------------------------------------------------------------------
# Implementations under test: case dict -> (b, g, p, n, hd) output
# ---------------------------------------------------------------------------

def _q8_operands(case, ctx_layout):
    kq, ks = quantize_ctx(case["kc"], fold_scale=HD**-0.5)  # (m_c, g)
    vq, vs = quantize_ctx(case["vc"])
    if ctx_layout == "gmk":
        return kq.transpose(1, 0, 2), vq.transpose(1, 0, 2), ks.T, vs.T
    return kq, vq, ks, vs


def _ctx(case, ctx_layout):
    if ctx_layout == "gmk":
        return case["kc"].transpose(1, 0, 2), case["vc"].transpose(1, 0, 2)
    return case["kc"], case["vc"]


def impl_einsum(case, ctx_layout, block_m):
    del block_m
    if ctx_layout == "gmk":  # paper 4-einsum reference is mgk-only
        from repro.core.bifurcated import bifurcated_attention_flash

        kc, vc = _ctx(case, ctx_layout)
        return bifurcated_attention_flash(
            case["q"], kc, vc, case["kd"], case["vd"],
            decode_mask=case["mask"], ctx_layout="gmk")
    return bifurcated_attention(
        case["q"], case["kc"], case["vc"], case["kd"], case["vd"],
        decode_mask=case["mask"])


def impl_einsum_q8(case, ctx_layout, block_m):
    del block_m
    kq, vq, ks, vs = _q8_operands(case, ctx_layout)
    return bifurcated_attention_q8(
        case["q"], kq, vq, ks, vs, case["kd"], case["vd"],
        decode_mask=case["mask"], ctx_layout=ctx_layout)


def impl_fused(case, ctx_layout, block_m):
    kc, vc = _ctx(case, ctx_layout)
    return bifurcated_decode_attention(
        case["q"], kc, vc, case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


def impl_two_pass(case, ctx_layout, block_m):
    kc, vc = _ctx(case, ctx_layout)
    return bifurcated_decode_attention(
        case["q"], kc, vc, case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout,
        two_pass=True)


def impl_fused_q8(case, ctx_layout, block_m):
    kq, vq, ks, vs = _q8_operands(case, ctx_layout)
    return bifurcated_decode_attention_q8(
        case["q"], kq, vq, ks, vs, case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


def _grouped_operands(case, ctx_layout):
    """Single-prefix case lifted to the forest dispatch: G=1 segment, all
    slots assigned to it, full context length."""
    b = case["q"].shape[0]
    m_c = case["kc"].shape[0]
    gids = jnp.zeros((b,), jnp.int32)
    clens = jnp.asarray([m_c], jnp.int32)
    return gids, clens


def impl_grouped(case, ctx_layout, block_m):
    kc, vc = _ctx(case, ctx_layout)
    gids, clens = _grouped_operands(case, ctx_layout)
    return grouped_bifurcated_decode_attention(
        case["q"], kc[None], vc[None], gids, clens,
        case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


def impl_grouped_q8(case, ctx_layout, block_m):
    kq, vq, ks, vs = _q8_operands(case, ctx_layout)
    gids, clens = _grouped_operands(case, ctx_layout)
    return grouped_bifurcated_decode_attention_q8(
        case["q"], kq[None], vq[None], ks[None], vs[None], gids, clens,
        case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


def impl_tree(case, ctx_layout, block_m):
    """Single-prefix case lifted to the trie dispatch: one node, depth-1
    paths — the cascade kernel's L=1 degenerate configuration."""
    kc, vc = _ctx(case, ctx_layout)
    gids, clens = _grouped_operands(case, ctx_layout)
    return tree_bifurcated_decode_attention(
        case["q"], kc[None], vc[None], gids[None], clens,
        case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


def impl_tree_q8(case, ctx_layout, block_m):
    kq, vq, ks, vs = _q8_operands(case, ctx_layout)
    gids, clens = _grouped_operands(case, ctx_layout)
    return tree_bifurcated_decode_attention_q8(
        case["q"], kq[None], vq[None], ks[None], vs[None], gids[None], clens,
        case["kd"], case["vd"], case["mask"],
        block_m=block_m, interpret=True, ctx_layout=ctx_layout)


def _paged_case(case, ctx_layout, block_m, q8=False):
    """Single-prefix case lifted to the PAGED dispatch: one segment whose
    pages land on a deterministically SHUFFLED pool
    (conftest.build_page_pool; page_m == block_m, so agreement with the
    dense kernels is bit-exact on full pages); paged storage is head-major
    only, so both ctx_layout parametrizations feed the same pool."""
    from conftest import build_page_pool
    from repro.core.paged import pages_needed

    del ctx_layout
    b = case["q"].shape[0]
    m_c = case["kc"].shape[0]
    cap = pages_needed(m_c, block_m) * block_m
    pad = lambda x: jnp.pad(                    # (g, m_c, ...) -> (1, g, cap, ...)
        x, ((0, 0), (0, cap - m_c)) + ((0, 0),) * (x.ndim - 2))[None]
    if q8:
        kq, ks = quantize_ctx(case["kc"].transpose(1, 0, 2),
                              fold_scale=HD**-0.5)      # (g, m_c, hd)
        vq, vs = quantize_ctx(case["vc"].transpose(1, 0, 2))
        arrays = [pad(kq), pad(vq), pad(ks), pad(vs)]
    else:
        arrays = [pad(case["kc"].transpose(1, 0, 2)),
                  pad(case["vc"].transpose(1, 0, 2))]
    pool, table = build_page_pool(arrays, [m_c], block_m,
                                  perm_seed=m_c + block_m)
    seg_lens = jnp.asarray([m_c], jnp.int32)
    paths = jnp.zeros((1, b), jnp.int32)
    return pool, table, seg_lens, paths


def impl_paged(case, ctx_layout, block_m):
    (kp, vp), table, seg_lens, paths = _paged_case(case, ctx_layout, block_m)
    return paged_bifurcated_decode_attention(
        case["q"], kp, vp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"], interpret=True)


def impl_paged_q8(case, ctx_layout, block_m):
    (kp, vp, ksp, vsp), table, seg_lens, paths = _paged_case(
        case, ctx_layout, block_m, q8=True)
    return paged_bifurcated_decode_attention_q8(
        case["q"], kp, vp, ksp, vsp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"], interpret=True)


def impl_packed(case, ctx_layout, block_m):
    """Single-prefix case on the PACKED work-queue dispatch with a
    DECODE-ONLY queue (no chunk attached): the queue degenerates to the
    live-page list and the kernel to the paged page walk."""
    (kp, vp), table, seg_lens, paths = _paged_case(case, ctx_layout, block_m)
    out_dec, _ = packed_bifurcated_decode_attention(
        case["q"], kp, vp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"], interpret=True)
    return out_dec


def impl_packed_q8(case, ctx_layout, block_m):
    (kp, vp, ksp, vsp), table, seg_lens, paths = _paged_case(
        case, ctx_layout, block_m, q8=True)
    out_dec, _ = packed_bifurcated_decode_attention_q8(
        case["q"], kp, vp, ksp, vsp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"], interpret=True)
    return out_dec


# name -> (fn, is_quantized). Quantized impls carry the int8 rounding error
# against the fp32 oracle; non-quantized ones only their dtype's.
IMPLS = {
    "einsum": (impl_einsum, False),
    "einsum_q8": (impl_einsum_q8, True),
    "fused": (impl_fused, False),
    "two_pass": (impl_two_pass, False),
    "fused_q8": (impl_fused_q8, True),
    "grouped": (impl_grouped, False),
    "grouped_q8": (impl_grouped_q8, True),
    "tree": (impl_tree, False),
    "tree_q8": (impl_tree_q8, True),
    "paged": (impl_paged, False),
    "paged_q8": (impl_paged_q8, True),
    "packed": (impl_packed, False),
    "packed_q8": (impl_packed_q8, True),
}

# per-dtype tolerance for exact (non-quantized) implementations
DTYPE_TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}
Q8_TOL = 3e-2   # int8 rounding bound vs the UNquantized fp32 oracle


def oracle(case):
    """fp32 monolithic softmax over [broadcast K_c ⊕ K_d] — ground truth."""
    f32 = lambda x: x.astype(jnp.float32)
    b = case["q"].shape[0]
    m_c = case["kc"].shape[0]
    K = jnp.concatenate(
        [jnp.broadcast_to(f32(case["kc"])[None], (b, *case["kc"].shape)),
         f32(case["kd"])], axis=1)
    V = jnp.concatenate(
        [jnp.broadcast_to(f32(case["vc"])[None], (b, *case["vc"].shape)),
         f32(case["vd"])], axis=1)
    valid = jnp.concatenate(
        [jnp.ones((b, m_c), bool), case["mask"]], axis=1)
    return decode_attention(f32(case["q"]), K, V, valid_mask=valid)


def _tol(name, dtype):
    _, quant = IMPLS[name]
    return Q8_TOL if quant else DTYPE_TOL[dtype]


def run_differential(case, *, dtype, ctx_layout, block_m):
    """Run every impl on one case; cross-check each vs the oracle and all
    pairs against each other. Returns the outputs for extra assertions."""
    ref = np.asarray(oracle(case), np.float32)
    scale = max(float(np.max(np.abs(ref))), 1.0)
    outs = {}
    for name, (fn, _) in IMPLS.items():
        out = np.asarray(fn(case, ctx_layout, block_m), np.float32)
        assert out.shape == ref.shape, (name, out.shape, ref.shape)
        assert not np.isnan(out).any(), f"{name} produced NaNs"
        err = np.max(np.abs(out - ref))
        tol = _tol(name, dtype)
        assert err <= tol * scale, f"{name} vs oracle: {err} > {tol}*{scale}"
        outs[name] = out
    names = sorted(outs)
    for i, a in enumerate(names):
        for bname in names[i + 1:]:
            tol = max(_tol(a, dtype), _tol(bname, dtype))
            err = np.max(np.abs(outs[a] - outs[bname]))
            assert err <= 2 * tol * scale, \
                f"{a} vs {bname}: {err} > 2*{tol}*{scale}"
    return outs


# (b, p, n, m_c, c_d, block_m) — m_c values include non-multiples of
# block_m (ragged ctx tails masked in-kernel) and block_m > m_c cells.
CASES = [
    (1, 1, 1, 64, 8, 64),
    (1, 4, 1, 130, 4, 128),     # ragged ctx tail, single sample
    (4, 1, 1, 300, 16, 128),    # ragged tail, mid batch
    (4, 4, 1, 257, 7, 128),     # prime-ish sizes
    (32, 1, 1, 512, 8, 256),    # large batch (paper's regime), aligned ctx
    (32, 4, 1, 96, 24, 128),    # large batch, block_m > m_c
    (3, 2, 4, 100, 12, 128),    # speculative n > 1 rows
]


@pytest.mark.parametrize("shape", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ctx_layout", ["mgk", "gmk"])
def test_differential_all_impls(shape, dtype, ctx_layout):
    b, p, n, m_c, c_d, block_m = shape
    case = make_decode_case(b, p, m_c, c_d, g=G, hd=HD, n=n, dtype=dtype,
                            seed=sum(shape))
    outs = run_differential(case, dtype=dtype, ctx_layout=ctx_layout,
                            block_m=block_m)
    if dtype == jnp.float32:
        # same scale-folded math, different execution order: fp32-tight
        # agreement (bf16 inputs round differently per path and are covered
        # by the generic pairwise tolerance above)
        np.testing.assert_allclose(outs["fused_q8"], outs["einsum_q8"],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(outs["grouped_q8"], outs["fused_q8"],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(outs["tree_q8"], outs["grouped_q8"],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(outs["paged_q8"], outs["tree_q8"],
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", CASES[:4])
@pytest.mark.parametrize("ctx_layout", ["mgk", "gmk"])
def test_grouped_g1_bit_identical_to_fused(shape, ctx_layout):
    """ISSUE acceptance: at G == 1 the grouped (forest) kernel reduces
    EXACTLY — bit-for-bit, not just within tolerance — to the single-prefix
    fused kernel (same block schedule, same online-update order)."""
    b, p, n, m_c, c_d, block_m = shape
    case = make_decode_case(b, p, m_c, c_d, g=G, hd=HD, n=n,
                            dtype=jnp.bfloat16, seed=sum(shape))
    out_g = impl_grouped(case, ctx_layout, block_m)
    out_f = impl_fused(case, ctx_layout, block_m)
    np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out_f))
    out_gq = impl_grouped_q8(case, ctx_layout, block_m)
    out_fq = impl_fused_q8(case, ctx_layout, block_m)
    np.testing.assert_array_equal(np.asarray(out_gq), np.asarray(out_fq))


@pytest.mark.parametrize("shape", CASES[:4])
@pytest.mark.parametrize("ctx_layout", ["mgk", "gmk"])
def test_tree_l1_bit_identical_to_fused(shape, ctx_layout):
    """ISSUE acceptance: at L=1 (a single shared prefix — one trie node,
    depth-1 paths) the cascade kernel reduces EXACTLY — bit-for-bit — to
    the single-prefix fused kernel, both dtypes."""
    b, p, n, m_c, c_d, block_m = shape
    case = make_decode_case(b, p, m_c, c_d, g=G, hd=HD, n=n,
                            dtype=jnp.bfloat16, seed=sum(shape))
    out_t = impl_tree(case, ctx_layout, block_m)
    out_f = impl_fused(case, ctx_layout, block_m)
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_f))
    out_tq = impl_tree_q8(case, ctx_layout, block_m)
    out_fq = impl_fused_q8(case, ctx_layout, block_m)
    np.testing.assert_array_equal(np.asarray(out_tq), np.asarray(out_fq))


def test_tree_l2_bit_identical_to_grouped():
    """ISSUE acceptance: at L=2 (a flat forest — depth-1 paths over G
    nodes) the cascade kernel reduces EXACTLY — bit-for-bit, not just
    within tolerance — to the grouped (forest) kernel on a mixed batch
    with ragged per-node lengths, both dtypes."""
    from repro.core.quantized import quantize_ctx as qc

    rng = np.random.RandomState(11)
    b, p, n, c_d = 6, 2, 1, 8
    n_groups, cap = 3, 160
    q = jnp.asarray(rng.randn(b, G, p, n, HD), jnp.bfloat16)
    kc = jnp.asarray(rng.randn(n_groups, G, cap, HD), jnp.bfloat16)   # gmk
    vc = jnp.asarray(rng.randn(n_groups, G, cap, HD), jnp.bfloat16)
    kd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.bfloat16)
    vd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.bfloat16)
    mask = jnp.arange(c_d)[None, :] < jnp.asarray(
        rng.randint(1, c_d + 1, size=(b,)))[:, None]
    gids = jnp.asarray([0, 1, 2, 0, 1, 0], jnp.int32)
    clens = jnp.asarray([160, 37, 96], jnp.int32)

    out_g = grouped_bifurcated_decode_attention(
        q, kc, vc, gids, clens, kd, vd, mask,
        block_m=64, interpret=True, ctx_layout="gmk")
    out_t = tree_bifurcated_decode_attention(
        q, kc, vc, gids[None], clens, kd, vd, mask,
        block_m=64, interpret=True, ctx_layout="gmk")
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_g))

    kq, ks = qc(kc, fold_scale=HD**-0.5)
    vq, vs = qc(vc)
    out_gq = grouped_bifurcated_decode_attention_q8(
        q, kq, vq, ks, vs, gids, clens, kd, vd, mask,
        block_m=64, interpret=True, ctx_layout="gmk")
    out_tq = tree_bifurcated_decode_attention_q8(
        q, kq, vq, ks, vs, gids[None], clens, kd, vd, mask,
        block_m=64, interpret=True, ctx_layout="gmk")
    np.testing.assert_array_equal(np.asarray(out_tq), np.asarray(out_gq))


@pytest.mark.parametrize("shape", CASES[:4])
def test_paged_bit_identical_to_tree(shape):
    """ISSUE acceptance: on fully-populated pages (page_m == the dense
    kernels' block_m, same logical contents, SHUFFLED pool pages) the
    paged page-walk kernel reduces EXACTLY — bit-for-bit — to the dense
    tree kernel, and hence (single segment, depth 1) to the fused kernel,
    both dtypes."""
    b, p, n, m_c, c_d, block_m = shape
    case = make_decode_case(b, p, m_c, c_d, g=G, hd=HD, n=n,
                            dtype=jnp.bfloat16, seed=sum(shape))
    out_p = impl_paged(case, "gmk", block_m)
    out_t = impl_tree(case, "gmk", block_m)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_t))
    out_pq = impl_paged_q8(case, "gmk", block_m)
    out_tq = impl_tree_q8(case, "gmk", block_m)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_tq))


@pytest.mark.parametrize("shape", CASES[:4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_bit_identical_to_paged(shape, dtype):
    """ISSUE acceptance: on a DECODE-ONLY work queue (no prefill chunk
    attached) the packed heterogeneous-step kernel reduces EXACTLY —
    bit-for-bit — to the paged page-walk kernel, both dtypes, both
    quantization modes, and the multi-launch chaining path is
    bit-identical to the single launch."""
    b, p, n, m_c, c_d, block_m = shape
    case = make_decode_case(b, p, m_c, c_d, g=G, hd=HD, n=n,
                            dtype=dtype, seed=sum(shape))
    out_k = impl_packed(case, "gmk", block_m)
    out_p = impl_paged(case, "gmk", block_m)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_p))
    out_kq = impl_packed_q8(case, "gmk", block_m)
    out_pq = impl_paged_q8(case, "gmk", block_m)
    np.testing.assert_array_equal(np.asarray(out_kq), np.asarray(out_pq))

    # multi-launch spill: cap the grid at 2 entries/launch
    (kp, vp), table, seg_lens, paths = _paged_case(case, "gmk", block_m)
    out_m, _ = packed_bifurcated_decode_attention(
        case["q"], kp, vp, table, seg_lens, paths,
        case["kd"], case["vd"], case["mask"], interpret=True,
        entries_per_launch=2)
    np.testing.assert_array_equal(np.asarray(out_m), np.asarray(out_k))


def test_grouped_multi_prefix_vs_per_group_fused():
    """G > 1: the forest kernel on a mixed batch must agree with running
    the single-prefix fused kernel once per group on that group's rows."""
    rng = np.random.RandomState(5)
    b, p, n, c_d = 6, 2, 1, 8
    n_groups, cap = 3, 160
    q = jnp.asarray(rng.randn(b, G, p, n, HD), jnp.float32)
    kc = jnp.asarray(rng.randn(n_groups, G, cap, HD), jnp.float32)   # gmk
    vc = jnp.asarray(rng.randn(n_groups, G, cap, HD), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
    mask = jnp.arange(c_d)[None, :] < jnp.asarray(
        rng.randint(1, c_d + 1, size=(b,)))[:, None]
    gids = jnp.asarray([0, 1, 2, 0, 1, 0], jnp.int32)
    clens = jnp.asarray([160, 37, 96], jnp.int32)

    out = grouped_bifurcated_decode_attention(
        q, kc, vc, gids, clens, kd, vd, mask,
        block_m=64, interpret=True, ctx_layout="gmk")
    for gi in range(n_groups):
        rows = np.where(np.asarray(gids) == gi)[0]
        m_i = int(clens[gi])
        ref = bifurcated_decode_attention(
            q[rows], kc[gi, :, :m_i], vc[gi, :, :m_i],
            kd[rows], vd[rows], mask[rows],
            block_m=64, interpret=True, ctx_layout="gmk")
        np.testing.assert_allclose(np.asarray(out[rows]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Optional hypothesis fuzz pass (CI: fixed-seed derandomized profile)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


if HAS_HYPOTHESIS:
    @given(
        b=st.integers(1, 8), p=st.integers(1, 3), n=st.integers(1, 3),
        m_c=st.integers(2, 160), c_d=st.integers(1, 12),
        full_mask=st.booleans(), gmk=st.booleans(),
        seed=st.integers(0, 10_000),
    )
    def test_differential_fuzz(b, p, n, m_c, c_d, full_mask, gmk, seed):
        """Hypothesis-driven shapes/seeds through the same harness (f32 so
        disagreements are decisive, smaller dims so interpret mode stays
        fast)."""
        case = make_decode_case(b, p, m_c, c_d, g=G, hd=HD, n=n,
                                dtype=jnp.float32, seed=seed,
                                full_mask=full_mask)
        run_differential(case, dtype=jnp.float32,
                         ctx_layout="gmk" if gmk else "mgk", block_m=128)

    @given(
        b=st.integers(1, 6),
        n_nodes=st.integers(1, 4),
        depth=st.integers(1, 3),
        page_m=st.sampled_from([16, 32, 64]),
        lens_seed=st.integers(0, 10_000),
        perm_seed=st.integers(0, 10_000),
    )
    def test_paged_fuzz_page_permutations_and_ragged_lens(
            b, n_nodes, depth, page_m, lens_seed, perm_seed):
        """Hypothesis fuzz for the PAGED path: random ragged node lengths
        (including FREE nodes), random slot paths, and a random PERMUTED
        page-pool assignment must stay bit-identical to the dense tree
        kernel on the same logical contents (f32, page_m == block_m)."""
        rng = np.random.RandomState(lens_seed)
        cap_pages = 3
        cap = cap_pages * page_m
        node_lens = rng.randint(0, cap + 1, size=(n_nodes,))
        if node_lens.max() == 0:
            node_lens[0] = 1                   # at least one live token
        kc = np.zeros((n_nodes, G, cap, HD), np.float32)
        vc = np.zeros_like(kc)
        for i, m in enumerate(node_lens):
            kc[i, :, :m] = rng.randn(G, m, HD)
            vc[i, :, :m] = rng.randn(G, m, HD)
        kc, vc = jnp.asarray(kc), jnp.asarray(vc)
        live = [i for i in range(n_nodes) if node_lens[i] > 0]
        paths = np.full((depth, b), -1, np.int64)
        for s in range(b):
            for lvl in range(rng.randint(1, depth + 1)):
                paths[lvl, s] = live[rng.randint(len(live))]
        paths = jnp.asarray(paths, jnp.int32)
        nlens = jnp.asarray(node_lens, jnp.int32)
        c_d = 4
        q = jnp.asarray(rng.randn(b, G, 1, 1, HD), jnp.float32)
        kd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
        vd = jnp.asarray(rng.randn(b, c_d, G, HD), jnp.float32)
        mask = jnp.arange(c_d)[None, :] < jnp.asarray(
            rng.randint(1, c_d + 1, size=(b,)))[:, None]

        # page the dense segments onto a permuted pool
        from conftest import build_page_pool

        (kp, vp), tables = build_page_pool(
            [kc, vc], node_lens, page_m, perm_seed=perm_seed,
            extra_pages=1)

        out_d = tree_bifurcated_decode_attention(
            q, kc, vc, paths, nlens, kd, vd, mask,
            block_m=page_m, interpret=True, ctx_layout="gmk")
        out_p = paged_bifurcated_decode_attention(
            q, kp, vp, tables, nlens, paths, kd, vd, mask, interpret=True)
        np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_d))


if HAS_HYPOTHESIS:
    from hypothesis import settings as _hyp_fuzz_settings

    _FUZZ_MODEL = {}

    def _fuzz_model():
        """Tiny real model, built once per process (hypothesis examples
        share it; each example gets a FRESH engine + allocator)."""
        if not _FUZZ_MODEL:
            from repro.configs.base import ModelConfig
            from repro.models import get_model

            cfg = ModelConfig(name="frontend-fuzz", family="dense",
                              n_layers=2, d_model=32, n_heads=2,
                              n_kv_heads=1, head_dim=16, d_ff=64,
                              vocab_size=64, vocab_pad_multiple=16,
                              decode_capacity=8)
            model = get_model(cfg)
            _FUZZ_MODEL.update(cfg=cfg, model=model,
                               params=model.init(jax.random.PRNGKey(0)))
        return _FUZZ_MODEL

    # engine jit-compiles per example — cap examples below the profile
    @_hyp_fuzz_settings(max_examples=8, deadline=None)
    @given(
        workload_seed=st.integers(0, 10_000),
        plan_seed=st.integers(0, 10_000),
        num_pages=st.integers(4, 7),
    )
    def test_frontend_fault_plan_fuzz(workload_seed, plan_seed, num_pages):
        """Hypothesis-driven robustness fuzz: a seeded random workload +
        a seeded random FaultPlan drawing from the FULL registered kind
        set — including ``kill_process`` (survived via DurableFrontend
        snapshot+journal recovery), ``snapshot_corrupt`` and
        ``journal_truncate`` — against an OVERSUBSCRIBED paged trie.
        Whatever the draw: no unhandled exception, every surviving
        ticket ends completed (EXACT token budget) or rejected-with-
        reason, the allocator audit passes at every round (original AND
        replayed), and every completed request's greedy tokens are
        BIT-IDENTICAL to its unkilled control (same plan minus the
        durability kinds, plain frontend). Requests are matched to the
        control BY CONTENT: journal truncation may legitimately lose
        tail submits, which shifts ticket ids."""
        import tempfile

        from repro.configs.base import TreeConfig
        from repro.runtime.faults import (
            FaultKind, FaultPlan, ProcessKilled)
        from repro.runtime.frontend import (
            COMPLETED, REJECTED, ServeFrontend)
        from repro.runtime.recovery import DurableFrontend
        from repro.runtime.serve import TreeServeEngine

        mp = _fuzz_model()
        cfg, model, params = mp["cfg"], mp["model"], mp["params"]

        def factory():
            return TreeServeEngine(model, cfg, TreeConfig(
                n_nodes=3, depth=2, slots=3, node_capacity=16,
                decode_capacity=8, temperature=0.0, ctx_store="paged",
                page_size=8, num_pages=num_pages))

        def workload(submit, pump):
            """Same seeded submit/pump schedule for both runs. Returns
            content-key -> budget (content determines greedy tokens, so
            it is the run-independent join key)."""
            rng = np.random.RandomState(workload_seed)
            prefixes = [rng.randint(0, cfg.vocab_size, (1, 10))
                        for _ in range(2)]
            budgets = {}
            for i in range(4):
                pfx = prefixes[int(rng.randint(2))]
                sfx = rng.randint(0, cfg.vocab_size,
                                  (1, int(rng.randint(2, 8))))
                mnt = int(rng.randint(3, 6))
                submit([jnp.asarray(pfx), jnp.asarray(sfx)],
                       n_samples=int(rng.randint(1, 3)),
                       max_new_tokens=mnt,
                       priority=int(rng.randint(0, 2)))
                key = (tuple(pfx[0].tolist()), tuple(sfx[0].tolist()))
                budgets[key] = mnt
                if i % 2:
                    pump()
            return budgets

        def content_key(t):
            return tuple(tuple(int(x) for x in np.asarray(s)[0])
                         for s in t.segments)

        durability = (FaultKind.KILL_PROCESS, FaultKind.SNAPSHOT_CORRUPT,
                      FaultKind.JOURNAL_TRUNCATE)
        plan_full = FaultPlan.random(plan_seed, rounds=10, rate=0.35)
        plan_ctrl = FaultPlan(
            [e for e in plan_full.events if e.kind not in durability],
            seed=plan_seed)

        # --- unkilled control: plain frontend, durability kinds stripped
        fe_c = ServeFrontend(factory(), fault_plan=plan_ctrl,
                             stall_rounds=4, max_attempts=6)
        state_c = fe_c.init_state()
        holder = {"s": state_c}

        def pump_c():
            holder["s"] = fe_c.pump(params, holder["s"])

        workload(fe_c.submit, pump_c)
        fe_c.drain(params, holder["s"], max_rounds=120)
        ctrl = {}
        for t in fe_c.tickets:
            if t.status == COMPLETED:
                ctrl[content_key(t)] = [
                    [int(x) for x in tok] for tok in t.tokens]

        # --- faulty run: DurableFrontend, full plan, kills survived
        with tempfile.TemporaryDirectory(prefix="fuzz_recov_") as d:
            dfe = DurableFrontend(
                factory, d, fault_plan=plan_full, snapshot_every=3,
                frontend_kwargs=dict(stall_rounds=4, max_attempts=6))
            dfe.init_state()

            def pump_once():
                """Advance exactly ONE net round, recovering through any
                kill — keeps the durable run's submit/round cadence
                aligned with the control's."""
                target = dfe.fe.round + 1
                guard = 0
                while dfe.fe.round < target:
                    guard += 1
                    assert guard < 50, "kill recovery did not converge"
                    try:
                        dfe.pump(params)
                    except ProcessKilled:
                        dfe.recover(params)

            budgets = workload(dfe.submit, pump_once)
            pumps = 0
            while dfe.pending():
                pumps += 1
                assert pumps < 120, "fuzz drain liveness failure"
                pump_once()

            for t in dfe.fe.tickets:
                assert t.status in (COMPLETED, REJECTED), (t.tid, t.status)
                key = content_key(t)
                if t.status == COMPLETED:
                    assert all(len(tok) == budgets[key] for tok in t.tokens)
                    if key in ctrl:
                        got = [[int(x) for x in tok] for tok in t.tokens]
                        assert got == ctrl[key], (
                            "greedy tokens diverged from unkilled control")
                else:
                    assert t.reason
            # every pump (original and replayed) ended with a green audit
            assert (dfe.fe.counters["audits_passed"]
                    >= dfe.fe.metrics()["rounds"])
