"""Validate the trip-count-aware HLO cost analyzer against ground truth:
scanned module cost ~= unrolled module cost ~= analytic GEMM flops."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch.hlo_cost import analyze


def _body(x, w):
    return jnp.tanh(x @ w), None


def f_scan(x, ws):
    y, _ = lax.scan(_body, x, ws)
    return y


def f_unroll(x, ws):
    for i in range(ws.shape[0]):
        x, _ = _body(x, ws[i])
    return x


def test_scan_trip_count_correction():
    L, d = 8, 64
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    scanned = jax.jit(f_scan).lower(x, ws).compile()
    unrolled = jax.jit(f_unroll).lower(x, ws).compile()

    analytic = 2.0 * L * d * d * d  # L matmuls
    ca = scanned.cost_analysis()
    if isinstance(ca, list):  # some jax versions wrap per-device
        ca = ca[0]
    xla_scan = ca["flops"]
    ours_scan = analyze(scanned.as_text())["flops"]
    ours_unroll = analyze(unrolled.as_text())["flops"]

    # XLA undercounts the scan by ~L; ours does not.
    assert xla_scan < analytic / 2, (xla_scan, analytic)
    assert abs(ours_scan - analytic) / analytic < 0.2, (ours_scan, analytic)
    assert abs(ours_unroll - analytic) / analytic < 0.2, (ours_unroll, analytic)
    # scanned ~= unrolled under our analyzer
    assert abs(ours_scan - ours_unroll) / ours_unroll < 0.25


def test_bytes_scale_with_trip_count():
    d = 64
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    for L in (4, 16):
        ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
        c = jax.jit(f_scan).lower(x, ws).compile()
        b = analyze(c.as_text())["bytes"]
        # weights alone are L*d*d*4 bytes; must be counted at least once each
        assert b >= L * d * d * 4, (L, b)


def test_dot_general_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    ours = analyze(c.as_text())["flops"]
    analytic = 2 * 4 * 32 * 64 * 16
    assert abs(ours - analytic) / analytic < 0.1, (ours, analytic)
