"""Out-of-process SIGKILL crash drill.

The in-process recovery tests (tests/test_recovery.py) simulate death
with ``ProcessKilled`` — the interpreter, the engine objects, and every
jit cache survive. This drill removes that safety net: a REAL serve
worker subprocess (tests/_crash_drill_worker.py) is SIGKILLed
mid-workload — no atexit, no finally blocks, nothing flushes — and a
second, FRESH interpreter recovers from the workdir's snapshot +
journal alone and finishes the workload. Its terminal results must be
bit-identical to an uninterrupted control run of the same seeded
workload, under BOTH admission policies (the sharing policy's journaled
admit order must replay divergence-free across a process boundary).
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(_HERE, "_crash_drill_worker.py")


def _env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(_HERE), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _run_worker(mode, workdir, policy, sleep_s="0"):
    subprocess.run([sys.executable, WORKER, mode, str(workdir), policy,
                    sleep_s], env=_env(), check=True, timeout=900)


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["fifo", "sharing"])
def test_sigkill_drill_recovers_bit_identical(tmp_path, policy):
    # uninterrupted control: same workload, its own interpreter
    ctrl_dir = tmp_path / "control"
    _run_worker("serve", ctrl_dir, policy)
    control = json.loads((ctrl_dir / "done.json").read_text())
    assert all(t["status"] == "completed" for t in control["tickets"])

    # the drill: a real worker, slowed per round so the kill window is
    # wide, SIGKILLed once it has pumped a few rounds
    drill_dir = tmp_path / "drill"
    proc = subprocess.Popen(
        [sys.executable, WORKER, "serve", str(drill_dir), policy, "0.3"],
        env=_env())
    try:
        progress = drill_dir / "progress.txt"
        deadline = time.time() + 600
        seen = -1
        while time.time() < deadline:
            if progress.exists():
                try:
                    seen = int(progress.read_text().split()[0])
                except (ValueError, IndexError):
                    pass   # racing the atomic rename; retry
                if seen >= 3:
                    break
            if proc.poll() is not None:
                pytest.fail(f"worker exited (rc={proc.returncode}) "
                            f"before the kill window")
            time.sleep(0.05)
        assert seen >= 3, "worker never reached the kill window"
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)
    assert proc.returncode == -signal.SIGKILL
    assert not (drill_dir / "done.json").exists(), \
        "kill landed after the workload already completed"

    # fresh interpreter, recovery from disk alone
    _run_worker("recover", drill_dir, policy)
    result = json.loads((drill_dir / "result.json").read_text())
    assert result["stats"]["recoveries"] == 1
    assert result["tickets"] == control["tickets"], (
        f"policy={policy}: recovered results diverged from the "
        f"uninterrupted control")
