"""Int8 context-KV quantization (core/quantized.py, beyond-paper §Perf):
round-trip accuracy, attention-path accuracy vs the fp path (both layouts,
logit scale pre-folded into k_scale), cache-family layout parity with
BifurcatedCache, and the end-to-end decode path through the model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.bifurcated import bifurcated_attention
from repro.core.kv_cache import BifurcatedCache
from repro.core.quantized import (
    QuantBifurcatedCache,
    bifurcated_attention_q8,
    dequantize_ctx,
    quantize_ctx,
)
from repro.models import get_model


def test_quantize_roundtrip_error_bounded():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 4, 32) * 2.0, jnp.float32)
    q, s = quantize_ctx(x)
    back = dequantize_ctx(q, s)
    # symmetric per-(token, head) int8: error bounded by scale/2 per element
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(jnp.max(s)) * 0.51


def test_quantize_fold_scale_prescales():
    """The logit scale folds into the returned scales (satellite: one fewer
    broadcast multiply per block on the hot loop)."""
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(16, 2, 32), jnp.float32)
    q0, s0 = quantize_ctx(x)
    q1, s1 = quantize_ctx(x, fold_scale=0.125)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0) * 0.125,
                               rtol=1e-6)


def test_q8_attention_close_to_fp():
    rng = np.random.RandomState(1)
    b, g, p, hd, m_c, c_d = 4, 2, 2, 32, 128, 16
    q = jnp.asarray(rng.randn(b, g, p, 1, hd), jnp.float32)
    kc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    vc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    # k_scale carries the attention logit scale pre-folded
    kq, ks = quantize_ctx(kc, fold_scale=hd**-0.5)
    vq, vs = quantize_ctx(vc)
    out_q = bifurcated_attention_q8(q, kq, vq, ks, vs, kd, vd)
    out_f = bifurcated_attention(q, kc, vc, kd, vd)
    np.testing.assert_allclose(out_q, out_f, rtol=0.05, atol=0.05)


def test_q8_attention_gmk_layout_matches_mgk():
    """Head-major "gmk" int8 context + "gmk"-shaped scales produce identical
    results to the sequence-major reference layout."""
    rng = np.random.RandomState(3)
    b, g, p, hd, m_c, c_d = 3, 2, 2, 32, 96, 8
    q = jnp.asarray(rng.randn(b, g, p, 1, hd), jnp.float32)
    kc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    vc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    kq, ks = quantize_ctx(kc, fold_scale=hd**-0.5)
    vq, vs = quantize_ctx(vc)
    out_mgk = bifurcated_attention_q8(q, kq, vq, ks, vs, kd, vd,
                                      ctx_layout="mgk")
    out_gmk = bifurcated_attention_q8(
        q, kq.transpose(1, 0, 2), vq.transpose(1, 0, 2), ks.T, vs.T, kd, vd,
        ctx_layout="gmk")
    np.testing.assert_allclose(out_mgk, out_gmk, rtol=1e-6, atol=1e-6)


def test_quant_cache_layout_aware_and_spec_parity():
    """Satellite: context_len is layout-aware and spec/from_prefill expose
    the same ctx_layout parameter surface as BifurcatedCache (drop-in
    interchangeable cache families)."""
    L, b, m_c, cd, g, hd = 2, 3, 24, 8, 2, 16
    for layout in ("gmk", "mgk"):
        spec_q = QuantBifurcatedCache.spec(L, b, m_c, cd, g, hd,
                                           ctx_layout=layout)
        spec_f = BifurcatedCache.spec(L, b, m_c, cd, g, hd,
                                      ctx_layout=layout)
        assert spec_q.context_len == spec_f.context_len == m_c
        assert spec_q.decode_capacity == spec_f.decode_capacity == cd
        assert spec_q.ctx_layout == layout
        assert spec_q.k_ctx.dtype == jnp.int8
        # int8 values carry the SAME axis order as the fp cache; scales drop
        # the trailing hd axis
        assert spec_q.k_ctx.shape == spec_f.k_ctx.shape
        assert spec_q.k_scale.shape == spec_f.k_ctx.shape[:-1]

    rng = np.random.RandomState(5)
    kf = jnp.asarray(rng.randn(L, m_c, g, hd), jnp.float32)
    vf = jnp.asarray(rng.randn(L, m_c, g, hd), jnp.float32)
    c_gmk = QuantBifurcatedCache.from_prefill(kf, vf, b, cd, ctx_layout="gmk")
    c_mgk = QuantBifurcatedCache.from_prefill(kf, vf, b, cd, ctx_layout="mgk")
    assert c_gmk.context_len == c_mgk.context_len == m_c
    assert c_gmk.k_ctx.shape == (L, g, m_c, hd)
    assert c_mgk.k_ctx.shape == (L, m_c, g, hd)
    # same quantization, different axis order
    np.testing.assert_array_equal(
        np.asarray(c_gmk.k_ctx), np.asarray(c_mgk.k_ctx.transpose(0, 2, 1, 3)))
    np.testing.assert_allclose(
        np.asarray(c_gmk.k_scale), np.asarray(c_mgk.k_scale.transpose(0, 2, 1)),
        rtol=1e-6)
    # k_scale is pre-folded with hd**-0.5; v_scale is not
    kq_raw, ks_raw = quantize_ctx(kf)
    np.testing.assert_allclose(np.asarray(c_mgk.k_scale),
                               np.asarray(ks_raw) * hd**-0.5, rtol=1e-6)


def test_decode_impl_io_bytes_quant_acceptance():
    """Acceptance: the modelled per-layer-step HBM traffic of the fused q8
    path undercuts bf16 fused >= 1.6x at (b=16, m_c=4096), and the
    context-arm-only traffic drops ~2x at production hd."""
    from repro.core.io_model import decode_impl_io_bytes, quantized_ctx_bytes

    kw = dict(b=16, p=1, n=1, m_c=4096, c_d=32, g=8, hd=64)
    io = {impl: decode_impl_io_bytes(impl=impl, **kw)
          for impl in ("einsum", "einsum_q8", "two_pass", "fused", "fused_q8")}
    assert io["fused"] / io["fused_q8"] >= 1.6, io
    assert io["fused_q8"] < io["fused"] < io["two_pass"] < io["einsum"]
    assert io["einsum_q8"] < io["einsum"]
    # context arm alone: 2*hd bytes vs hd + 4 (f32 scale) per (token, head)
    ctx_bf16 = 2 * 8 * 4096 * 128 * 2
    assert ctx_bf16 / quantized_ctx_bytes(m_c=4096, g=8, hd=128) > 1.9


def test_quant_cache_pspec_tree_layout_aware():
    """Sharding specs shard the context sequence dim of the int8 values AND
    the scale leaves identically under both layouts."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.steps import cache_pspec_tree

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for layout in ("gmk", "mgk"):
        spec = QuantBifurcatedCache.spec(2, 4, 32, 8, 2, 16,
                                         ctx_layout=layout)
        ps = cache_pspec_tree(mesh, spec)
        assert ps.ctx_layout == layout
        if layout == "gmk":   # (L, g, m_c, hd) / (L, g, m_c)
            assert ps.k_ctx == P(None, None, "model", None)
            assert ps.k_scale == P(None, None, "model")
        else:                 # (L, m_c, g, hd) / (L, m_c, g)
            assert ps.k_ctx == P(None, "model", None, None)
            assert ps.k_scale == P(None, "model", None)


def test_model_decode_with_q8_cache():
    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    b, m_c = 3, 24
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, m_c)))
    cont = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, 3)))
    _, c1 = model.prefill(params, ctx, None)

    cache_fp = BifurcatedCache.from_prefill(c1.k[:, 0], c1.v[:, 0], b, 16,
                                            dtype=c1.k.dtype,
                                            ctx_layout=cfg.ctx_layout)
    cache_q8 = QuantBifurcatedCache.from_prefill(
        c1.k[:, 0].astype(jnp.float32), c1.v[:, 0].astype(jnp.float32), b, 16,
        ctx_layout=cfg.ctx_layout)
    scale = None
    for t in range(3):
        lf, cache_fp = model.decode_step(params, cache_fp, cont[:, t:t + 1], None)
        lq, cache_q8 = model.decode_step(params, cache_q8, cont[:, t:t + 1], None)
        scale = float(jnp.max(jnp.abs(lf)))
        err = float(jnp.max(jnp.abs(lf - lq)))
        assert err < 0.1 * max(scale, 1.0), (t, err, scale)
    # int8 context cache halves the bytes (modulo the per-(token,head)
    # scale overhead: 4/hd — 25% at this toy hd=16, 3% at production hd=128)
    fp_bytes = cache_fp.k_ctx.size * 2
    q8_bytes = cache_q8.k_ctx.size * 1 + cache_q8.k_scale.size * 4
    assert q8_bytes < 0.7 * fp_bytes
    hd = 128  # production head dim
    assert (hd + 4) / (2 * hd) < 0.52


def test_encdec_decode_with_q8_cache():
    """Whisper-style enc-dec: int8 self-attention context arm via
    ctx_quant="int8" tracks the bf16 bifurcated path."""
    cfg = reduced_config(get_config("whisper-medium"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(6)
    b = 3
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 12)))
    frames = jnp.asarray(rng.randn(1, 16, cfg.d_model) * 0.02, jnp.float32)
    cont = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, 2)))
    _, c_fp = model.prefill(params, ctx, None, frames=frames, bifurcated=True,
                            sample_batch=b)
    _, c_q8 = model.prefill(params, ctx, None, frames=frames, bifurcated=True,
                            sample_batch=b, ctx_quant="int8")
    assert isinstance(c_q8["self"], QuantBifurcatedCache)
    assert c_q8["self"].k_ctx.dtype == jnp.int8
    for t in range(2):
        lf, c_fp = model.decode_step(params, c_fp, cont[:, t:t + 1], None)
        lq, c_q8 = model.decode_step(params, c_q8, cont[:, t:t + 1], None)
        scale = float(jnp.max(jnp.abs(lf)))
        assert float(jnp.max(jnp.abs(lf - lq))) < 0.1 * max(scale, 1.0)
    assert isinstance(c_q8["self"], QuantBifurcatedCache)  # survives decode


def test_hybrid_decode_with_q8_cache():
    """Zamba2-style hybrid: the shared attention block's context arm
    quantizes via ctx_quant="int8" and tracks the bf16 path."""
    cfg = reduced_config(get_config("zamba2-7b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(8)
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 12)))
    cont = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 2)))
    _, c_fp = model.prefill(params, ctx, None, bifurcated=True)
    _, c_q8 = model.prefill(params, ctx, None, bifurcated=True,
                            ctx_quant="int8")
    assert isinstance(c_q8["attn"], QuantBifurcatedCache)
    for t in range(2):
        lf, c_fp = model.decode_step(params, c_fp, cont[:, t:t + 1], None)
        lq, c_q8 = model.decode_step(params, c_q8, cont[:, t:t + 1], None)
        scale = float(jnp.max(jnp.abs(lf)))
        assert float(jnp.max(jnp.abs(lf - lq))) < 0.1 * max(scale, 1.0)
    assert isinstance(c_q8["attn"], QuantBifurcatedCache)


def test_hybrid_serve_engine_int8_cache_not_ignored():
    """Regression: ServeEngine(cache_dtype="int8") must reach the hybrid
    family too — prefill_shared injects ctx_quant and the broadcast keeps
    the quantized cache family."""
    from repro.configs import ServeConfig
    from repro.core.policy import BifurcationPolicy
    from repro.runtime.serve import ServeEngine

    cfg = reduced_config(get_config("zamba2-7b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctx = jnp.asarray(np.random.RandomState(9).randint(
        0, cfg.vocab_size, (1, 12)))
    scfg = ServeConfig(batch=3, decode_capacity=24, temperature=0.0,
                       cache_dtype="int8")
    eng = ServeEngine(model, cfg, scfg,
                      policy=BifurcationPolicy(enabled=True,
                                               min_io_saving_bytes=0))
    _, cache = eng.prefill_shared(params, ctx, 3)
    assert isinstance(cache["attn"], QuantBifurcatedCache)
    assert cache["attn"].k_ctx.dtype == jnp.int8
    assert cache["attn"].k_dec.shape[1] == 3  # decode arm broadcast to batch
    # the decode arm is sized from the SERVE config, not cfg.decode_capacity
    assert cache["attn"].decode_capacity == scfg.decode_capacity
    r = eng.generate(params, ctx, n_steps=3, key=jax.random.PRNGKey(0))
    assert r.tokens.shape == (3, 3)
    assert np.isfinite(np.asarray(r.logprobs)).all()


def test_model_decode_q8_kernel_impl_matches_einsum():
    """decode_step(impl="kernel") on a quantized cache routes through the
    fused q8 Pallas kernel and matches the q8 einsum reference path."""
    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    b, m_c = 3, 24
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, m_c)))
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, 1)))
    _, c1 = model.prefill(params, ctx, None)
    cache = QuantBifurcatedCache.from_prefill(
        c1.k[:, 0].astype(jnp.float32), c1.v[:, 0].astype(jnp.float32), b, 16,
        ctx_layout=cfg.ctx_layout)
    lk, ck = model.decode_step(params, cache, tok, None, impl="kernel")
    le, ce = model.decode_step(params, cache, tok, None, impl="einsum")
    assert isinstance(ck, QuantBifurcatedCache)
    assert ck.ctx_layout == cfg.ctx_layout
    scale = float(jnp.max(jnp.abs(le)))
    assert float(jnp.max(jnp.abs(lk - le))) < 0.05 * max(scale, 1.0)
