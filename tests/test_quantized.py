"""Int8 context-KV quantization (core/quantized.py, beyond-paper §Perf):
round-trip accuracy, attention-path accuracy vs the fp path, and the
end-to-end decode path through the model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.bifurcated import bifurcated_attention
from repro.core.quantized import (
    QuantBifurcatedCache,
    bifurcated_attention_q8,
    dequantize_ctx,
    quantize_ctx,
)
from repro.models import get_model


def test_quantize_roundtrip_error_bounded():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 4, 32) * 2.0, jnp.float32)
    q, s = quantize_ctx(x)
    back = dequantize_ctx(q, s)
    # symmetric per-(token, head) int8: error bounded by scale/2 per element
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(jnp.max(s)) * 0.51


def test_q8_attention_close_to_fp():
    rng = np.random.RandomState(1)
    b, g, p, hd, m_c, c_d = 4, 2, 2, 32, 128, 16
    q = jnp.asarray(rng.randn(b, g, p, 1, hd), jnp.float32)
    kc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    vc = jnp.asarray(rng.randn(m_c, g, hd), jnp.float32)
    kd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    vd = jnp.asarray(rng.randn(b, c_d, g, hd), jnp.float32)
    kq, ks = quantize_ctx(kc)
    vq, vs = quantize_ctx(vc)
    out_q = bifurcated_attention_q8(q, kq, vq, ks, vs, kd, vd)
    out_f = bifurcated_attention(q, kc, vc, kd, vd)
    np.testing.assert_allclose(out_q, out_f, rtol=0.05, atol=0.05)


def test_model_decode_with_q8_cache():
    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    b, m_c = 3, 24
    ctx = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, m_c)))
    cont = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, 3)))
    _, c1 = model.prefill(params, ctx, None)
    from repro.core.kv_cache import BifurcatedCache

    cache_fp = BifurcatedCache.from_prefill(c1.k[:, 0], c1.v[:, 0], b, 16,
                                            dtype=c1.k.dtype)
    cache_q8 = QuantBifurcatedCache.from_prefill(
        c1.k[:, 0].astype(jnp.float32), c1.v[:, 0].astype(jnp.float32), b, 16)
    scale = None
    for t in range(3):
        lf, cache_fp = model.decode_step(params, cache_fp, cont[:, t:t + 1], None)
        lq, cache_q8 = model.decode_step(params, cache_q8, cont[:, t:t + 1], None)
        scale = float(jnp.max(jnp.abs(lf)))
        err = float(jnp.max(jnp.abs(lf - lq)))
        assert err < 0.1 * max(scale, 1.0), (t, err, scale)
    # int8 context cache halves the bytes (modulo the per-(token,head)
    # scale overhead: 4/hd — 25% at this toy hd=16, 3% at production hd=128)
    fp_bytes = cache_fp.k_ctx.size * 2
    q8_bytes = cache_q8.k_ctx.size * 1 + cache_q8.k_scale.size * 4
    assert q8_bytes < 0.7 * fp_bytes
    hd = 128  # production head dim
    assert (hd + 4) / (2 * hd) < 0.52
