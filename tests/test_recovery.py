"""Crash-consistent serving: journal, snapshots, replay recovery, KV guards.

Fast (host-only) tier:
  * ``runtime/journal.Journal`` — durable append, seq+CRC guarding, torn
    tail / corrupt line / seq-break detection, missing-file semantics;
  * ``checkpoint.ServeCheckpointer`` — atomic snapshots with BIT-EXACT
    round-trips for bf16/int8 leaves, per-leaf CRC verification, host
    blob CRC, quarantine-and-fall-back in ``load_latest``, template
    compatibility rejection;
  * ``runtime/faults.FaultPlan`` — rng_state round-trip, ``disable``,
    registry-derived ``FaultKind.ALL``.

Slow tier (real tiny model + engines, CPU) — the PR acceptance bar:
  * KILL-AND-RESTORE BIT-IDENTITY: a DurableFrontend killed mid-workload
    (twice) and recovered from snapshot + journal replay completes every
    request with greedy tokens bit-identical to an uninterrupted control
    — across forest/tree x dense/paged x bf16/int8;
  * snapshot corruption detected by checksums, quarantined, recovery
    falls back to the previous valid snapshot;
  * journal truncation: replay stops at the last complete record and the
    run still converges deterministically;
  * the NaN/Inf decode sentinel quarantines ONLY the poisoned request
    (typed ``kv_corruption`` rejection) while neighbours complete;
  * ``audit_state(verify_checksums=True)`` raises ``KVCorruption`` on a
    flipped live KV byte;
  * a stale heartbeat surfaces as ``StaleHeartbeat`` and the supervised
    loop restarts from checkpoint and finishes the workload.
"""
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ServeCheckpointer
from repro.core.errors import KVCorruption
from repro.runtime.faults import FaultEvent, FaultKind, FaultPlan
from repro.runtime.journal import Journal


# ---------------------------------------------------------------------------
# Fast: journal
# ---------------------------------------------------------------------------

def test_journal_append_read_roundtrip(tmp_path):
    p = str(tmp_path / "j.log")
    j = Journal(p)
    recs = [{"ev": "submit", "tid": 0}, {"ev": "round", "round": 1,
                                         "obs": [{"ev": "admit"}]}]
    for r in recs:
        j.append(r)
    j.close()
    got, clean = Journal.read(p)
    assert clean and got == recs


def test_journal_missing_file_reads_clean(tmp_path):
    got, clean = Journal.read(str(tmp_path / "nope.log"))
    assert got == [] and clean


def test_journal_torn_tail_detected(tmp_path):
    p = str(tmp_path / "j.log")
    j = Journal(p)
    for i in range(3):
        j.append({"i": i})
    j.close()
    # chop mid-record: the tail line loses its newline and part of itself
    os.truncate(p, os.path.getsize(p) - 5)
    got, clean = Journal.read(p)
    assert not clean
    assert got == [{"i": 0}, {"i": 1}]   # records before the tear trusted


def test_journal_crc_guards_each_line(tmp_path):
    p = str(tmp_path / "j.log")
    j = Journal(p)
    j.append({"i": 0})
    j.append({"i": 1})
    j.close()
    raw = open(p, "rb").read().splitlines(keepends=True)
    # flip a payload byte inside the SECOND record, keep its length
    line = bytearray(raw[1])
    line[-3] ^= 0x01
    open(p, "wb").write(raw[0] + bytes(line))
    got, clean = Journal.read(p)
    assert not clean and got == [{"i": 0}]


def test_journal_seq_break_detected(tmp_path):
    p = str(tmp_path / "j.log")
    j = Journal(p)
    j.append({"i": 0})
    j.close()
    # append a record with a WRONG seq (2, not 1) but a valid CRC
    import zlib
    payload = json.dumps({"i": "rogue"}, separators=(",", ":"))
    with open(p, "a") as f:
        f.write(f"2 {zlib.crc32(payload.encode()):08x} {payload}\n")
    got, clean = Journal.read(p)
    assert not clean and got == [{"i": 0}]


def test_journal_reopen_continues_seq(tmp_path):
    p = str(tmp_path / "j.log")
    j = Journal(p)
    j.append({"i": 0})
    j.close()
    j2 = Journal(p)
    assert j2.seq == 1
    j2.append({"i": 1})
    j2.close()
    got, clean = Journal.read(p)
    assert clean and got == [{"i": 0}, {"i": 1}]


def test_journal_compact_drops_torn_tail_and_appends_readably(tmp_path):
    """``compact`` rewrites a torn epoch down to its clean prefix, so an
    epoch re-opened for appends (recovery that must defer its snapshot)
    chains new records READABLY instead of burying them past the tear."""
    p = str(tmp_path / "j.log")
    j = Journal(p)
    for i in range(3):
        j.append({"i": i})
    j.close()
    os.truncate(p, os.path.getsize(p) - 5)       # tear the last record
    assert Journal.compact(p) == 2
    got, clean = Journal.read(p)
    assert clean and got == [{"i": 0}, {"i": 1}]
    j2 = Journal(p)                               # appends continue the seq
    assert j2.seq == 2
    j2.append({"i": 9})
    j2.close()
    got, clean = Journal.read(p)
    assert clean and got == [{"i": 0}, {"i": 1}, {"i": 9}]


def test_journal_compact_leaves_clean_file_untouched(tmp_path):
    p = str(tmp_path / "j.log")
    j = Journal(p)
    j.append({"i": 0})
    j.close()
    before = open(p, "rb").read()
    assert Journal.compact(p) == 1
    assert open(p, "rb").read() == before


# ---------------------------------------------------------------------------
# Fast: ServeCheckpointer
# ---------------------------------------------------------------------------

def _device_state():
    import ml_dtypes
    rng = np.random.RandomState(0)
    return {
        "pool": jnp.asarray(rng.randn(2, 4, 8).astype(ml_dtypes.bfloat16)),
        "scales": jnp.asarray(rng.randint(-127, 127, (2, 4), dtype=np.int8)),
        "lens": jnp.asarray(rng.randint(0, 9, (4,), dtype=np.int32)),
    }


def _like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def test_serve_ckpt_bit_exact_roundtrip(tmp_path):
    ck = ServeCheckpointer(str(tmp_path))
    dev = _device_state()
    host = {"round": 5, "tickets": [1, 2, 3]}
    ck.save(5, dev, host)
    r, dev2, host2 = ck.load_latest(_like(dev))
    assert r == 5 and host2 == host
    for k in dev:
        a, b = np.asarray(dev[k]), np.asarray(dev2[k])
        assert a.dtype == b.dtype
        assert a.tobytes() == b.tobytes()      # BIT exact, incl. bf16/int8


def test_serve_ckpt_detects_bit_flip_and_falls_back(tmp_path):
    ck = ServeCheckpointer(str(tmp_path))
    dev = _device_state()
    ck.save(2, dev, {"round": 2})
    ck.save(4, dev, {"round": 4})
    # flip one byte inside the NEWEST snapshot's array bytes
    path = os.path.join(ck.path_for(4), "arrays.bin")
    with open(path, "r+b") as f:
        f.seek(3)
        b = f.read(1)
        f.seek(3)
        f.write(bytes([b[0] ^ 0x10]))
    with pytest.raises(KVCorruption):
        ck.load(4, _like(dev))
    r, dev2, host2 = ck.load_latest(_like(dev))
    assert r == 2 and host2 == {"round": 2}     # fell back
    # the bad snapshot is quarantined out of the namespace, kept on disk
    assert ck.all_rounds() == [2]
    assert os.path.exists(ck.path_for(4) + ".corrupt")


def test_serve_ckpt_no_valid_snapshot_raises(tmp_path):
    ck = ServeCheckpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.load_latest({"x": jnp.zeros(2)})


def test_serve_ckpt_host_blob_crc(tmp_path):
    ck = ServeCheckpointer(str(tmp_path))
    dev = _device_state()
    ck.save(1, dev, {"secret": "payload"})
    meta_path = os.path.join(ck.path_for(1), "meta.json")
    meta = json.loads(open(meta_path).read())
    meta["host"] = meta["host"].replace("payload", "tampered")
    open(meta_path, "w").write(json.dumps(meta))
    with pytest.raises(KVCorruption):
        ck.load(1, _like(dev))


def test_serve_ckpt_rejects_incompatible_template(tmp_path):
    ck = ServeCheckpointer(str(tmp_path))
    dev = _device_state()
    ck.save(1, dev, {})
    bad = dict(_like(dev))
    bad["extra"] = jnp.zeros(3)
    with pytest.raises(KVCorruption, match="incompatible"):
        ck.load(1, bad)


def test_serve_ckpt_validate_hook_triggers_fallback(tmp_path):
    ck = ServeCheckpointer(str(tmp_path))
    dev = _device_state()
    ck.save(2, dev, {"round": 2})
    ck.save(4, dev, {"round": 4})

    def validate(round_, device_state, host):
        if round_ == 4:
            raise KVCorruption("engine-level verification failed")

    r, _, _ = ck.load_latest(_like(dev), validate=validate)
    assert r == 2
    assert os.path.exists(ck.path_for(4) + ".corrupt")


def test_serve_ckpt_gc_keeps_last_k(tmp_path):
    ck = ServeCheckpointer(str(tmp_path), keep_last_k=2)
    dev = _device_state()
    for r in (1, 2, 3, 4):
        ck.save(r, dev, {})
    assert ck.all_rounds() == [3, 4]


# ---------------------------------------------------------------------------
# Fast: FaultPlan durability surface
# ---------------------------------------------------------------------------

def test_fault_kind_registry_includes_durability_kinds():
    for k in ("kill_process", "snapshot_corrupt", "journal_truncate"):
        assert k in FaultKind.ALL
    assert FaultKind.ALL == FaultKind.registered()


def test_fault_plan_random_draws_all_registered_kinds():
    plan = FaultPlan.random(3, rounds=4000, rate=1.0)
    assert set(plan.counts()) == set(FaultKind.registered())


def test_fault_plan_rng_state_roundtrip():
    a, b = FaultPlan(seed=5), FaultPlan(seed=5)
    seq = list(range(20))
    [a.choose(seq) for _ in range(3)]
    b.set_rng_state(a.rng_state())
    assert [a.choose(seq) for _ in range(10)] == \
           [b.choose(seq) for _ in range(10)]


def test_fault_plan_rng_state_json_roundtrip():
    a = FaultPlan(seed=9)
    a.choose(list(range(10)))
    state = json.loads(json.dumps(a.rng_state()))
    b = FaultPlan(seed=0).set_rng_state(state)
    assert a.choose(list(range(10))) == b.choose(list(range(10)))


def test_fault_plan_disable():
    plan = FaultPlan([FaultEvent(2, FaultKind.KILL_PROCESS),
                      FaultEvent(5, FaultKind.KILL_PROCESS),
                      FaultEvent(5, FaultKind.POOL_EXHAUST)])
    assert plan.disable(FaultKind.KILL_PROCESS, upto_round=4) == 1
    assert [(e.round, e.kind) for e in plan.events] == [
        (5, FaultKind.KILL_PROCESS), (5, FaultKind.POOL_EXHAUST)]


# ---------------------------------------------------------------------------
# Slow: engines — kill-and-restore bit-identity, guards, supervision
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs.base import ModelConfig
    from repro.models import get_model

    cfg = ModelConfig(name="recovery-test", family="dense",
                      n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64, vocab_pad_multiple=16,
                      decode_capacity=8)
    model = get_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


RNG = np.random.RandomState(0)
SYS_TOKS = RNG.randint(0, 64, (1, 12))
REQ_TOKS = [RNG.randint(0, 64, (1, 7)) for _ in range(4)]
# second shared prefix + six suffixes for the sharing-policy replay
# drills: submissions alternate the two prefixes, so the sharing
# policy's greedy order (same-prefix siblings first) differs from FIFO
ALT_TOKS = RNG.randint(0, 64, (1, 12))
SHARED_REQ_TOKS = [RNG.randint(0, 64, (1, 7)) for _ in range(6)]


def _factory(cfg, model, kind: str, store: str, dtype: str):
    from repro.configs.base import ForestConfig, TreeConfig
    from repro.runtime.serve import ForestServeEngine, TreeServeEngine

    if kind == "tree":
        def make():
            return TreeServeEngine(model, cfg, TreeConfig(
                n_nodes=6, depth=2, slots=4, node_capacity=16,
                decode_capacity=8, temperature=0.0, cache_dtype=dtype,
                ctx_store=store, page_size=8, num_pages=8))
    else:
        def make():
            return ForestServeEngine(model, cfg, ForestConfig(
                n_groups=3, slots=4, ctx_capacity=24, decode_capacity=8,
                temperature=0.0, cache_dtype=dtype, ctx_store=store,
                page_size=8, num_pages=10))
    return make


def _submit_all(fe_like):
    sys_ = jnp.asarray(SYS_TOKS)
    for r in REQ_TOKS:
        fe_like.submit([sys_, jnp.asarray(r)], n_samples=1,
                       max_new_tokens=5)


def _results(tickets):
    return ({t.tid: [list(map(int, x)) for x in (t.tokens or [])]
             for t in tickets},
            {t.tid: t.status for t in tickets})


def _control(factory, params):
    from repro.runtime.frontend import ServeFrontend

    fe = ServeFrontend(factory(), queue_depth=32, decode_steps=1)
    st = fe.init_state()
    _submit_all(fe)
    fe.drain(params, st)
    return _results(fe.tickets)


def _durable_run(factory, params, plan, tmpdir, snapshot_every=2):
    from repro.runtime.faults import ProcessKilled
    from repro.runtime.recovery import DurableFrontend

    dfe = DurableFrontend(factory, tmpdir, fault_plan=plan,
                          snapshot_every=snapshot_every,
                          frontend_kwargs=dict(queue_depth=32,
                                               decode_steps=1))
    dfe.init_state()
    _submit_all(dfe)
    pumps = 0
    while dfe.pending():
        pumps += 1
        assert pumps < 200, "recovery liveness failure"
        try:
            dfe.pump(params)
        except ProcessKilled:
            dfe.recover(params)
    return dfe


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["tree", "forest"])
@pytest.mark.parametrize("store", ["paged", "dense"])
@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
def test_kill_and_restore_bit_identical(tiny_model, tmp_path, kind, store,
                                        dtype):
    """THE acceptance test: kill the engine mid-workload (twice), recover
    from snapshot + journal replay, and finish — every request completes
    with greedy tokens BIT-IDENTICAL to an uninterrupted control, across
    engine family x storage substrate x cache dtype."""
    cfg, model, params = tiny_model
    factory = _factory(cfg, model, kind, store, dtype)
    ctrl_tokens, ctrl_status = _control(factory, params)
    plan = FaultPlan([FaultEvent(2, FaultKind.KILL_PROCESS),
                      FaultEvent(4, FaultKind.KILL_PROCESS)])
    dfe = _durable_run(factory, params, plan, str(tmp_path))
    got_tokens, got_status = _results(dfe.fe.tickets)
    assert dfe.stats["recoveries"] == 2
    assert got_status == ctrl_status
    assert got_tokens == ctrl_tokens
    # audits stayed green on every round of both timelines
    assert dfe.fe.counters["audits_passed"] > 0


@pytest.mark.slow
def test_snapshot_corruption_falls_back_to_previous(tiny_model, tmp_path):
    """A bit-flipped snapshot must be DETECTED (per-leaf CRC), quarantined,
    and recovery lands on the previous valid snapshot — still finishing
    bit-identically (the journal tail is just longer)."""
    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    ctrl_tokens, ctrl_status = _control(factory, params)
    plan = FaultPlan([FaultEvent(3, FaultKind.SNAPSHOT_CORRUPT, arg=3),
                      FaultEvent(4, FaultKind.KILL_PROCESS)])
    dfe = _durable_run(factory, params, plan, str(tmp_path))
    assert dfe.stats["recoveries"] == 1
    assert dfe.stats["snapshot_fallbacks"] >= 1
    assert any(n.endswith(".corrupt")
               for n in os.listdir(dfe.ckpt.directory))
    got_tokens, got_status = _results(dfe.fe.tickets)
    assert got_status == ctrl_status and got_tokens == ctrl_tokens


@pytest.mark.slow
def test_journal_truncation_replay_stops_cleanly(tiny_model, tmp_path):
    """Chopping the live journal's tail loses records but NOT consistency:
    replay stops at the last complete record and the resumed run still
    completes every surviving request bit-identically."""
    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    ctrl_tokens, ctrl_status = _control(factory, params)
    plan = FaultPlan([FaultEvent(3, FaultKind.JOURNAL_TRUNCATE, arg=40),
                      FaultEvent(4, FaultKind.KILL_PROCESS)])
    dfe = _durable_run(factory, params, plan, str(tmp_path),
                       snapshot_every=8)
    assert dfe.stats["recoveries"] == 1
    got_tokens, got_status = _results(dfe.fe.tickets)
    # this workload's submits all land in the round-0 epoch before the
    # truncation point, so every request survives here
    assert got_status == ctrl_status and got_tokens == ctrl_tokens


class TickClock:
    """Injected clock: every call returns the current time, then advances
    it by a fixed ``dt`` — so each (start, stop) pair the frontend takes
    around one journal record measures EXACTLY ``dt`` seconds, making the
    budget cadence a deterministic function of record counts."""

    def __init__(self, dt: float = 1.0):
        self.t = 0.0
        self.dt = dt

    def __call__(self) -> float:
        v = self.t
        self.t += self.dt
        return v


@pytest.mark.slow
def test_snapshot_budget_cadence_with_injected_clock(tiny_model, tmp_path):
    """With ``snapshot_budget_s`` set, snapshots fire when the ESTIMATED
    replay time of the journal tail crosses the budget — not on the
    fixed round cadence. The injected clock makes every record cost
    exactly 1s, so the cadence is predictable to the round: 5 records
    (4 submits + 1 round) trip a 3.5s budget at round 1, then every 4th
    round after."""
    from repro.runtime.recovery import DurableFrontend

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    clk = TickClock(1.0)
    dfe = DurableFrontend(factory, str(tmp_path), snapshot_every=8,
                          snapshot_budget_s=3.5, clock=clk,
                          frontend_kwargs=dict(queue_depth=32,
                                               decode_steps=1))
    dfe.init_state()                              # base snapshot, round 0
    _submit_all(dfe)                              # 4 records @ 1s each
    assert dfe.estimated_replay_s() == pytest.approx(4.0)
    dfe.pump(params)                              # 5 records > 3.5s budget
    assert sorted(dfe.ckpt.all_rounds()) == [0, 1]
    assert dfe.estimated_replay_s() == 0.0        # tail reset by snapshot
    for _ in range(3):                            # 1s, 2s, 3s — under budget
        dfe.pump(params)
    assert sorted(dfe.ckpt.all_rounds()) == [0, 1]
    assert dfe.estimated_replay_s() == pytest.approx(3.0)
    dfe.pump(params)                              # 4s > 3.5s: round 5, NOT 8
    assert max(dfe.ckpt.all_rounds()) == 5
    assert dfe.stats["snapshots"] == 3
    assert dfe.metrics()["durability"]["estimated_replay_s"] == 0.0


@pytest.mark.slow
def test_recovery_remeasures_replay_cost(tiny_model, tmp_path):
    """An actual replay re-measures the per-record cost directly (the
    live-execution EMA is only a proxy) and the recovered run still
    finishes bit-identically under budget cadence."""
    from repro.runtime.faults import ProcessKilled
    from repro.runtime.recovery import DurableFrontend

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    ctrl_tokens, ctrl_status = _control(factory, params)
    plan = FaultPlan([FaultEvent(2, FaultKind.KILL_PROCESS)])
    clk = TickClock(1.0)
    dfe = DurableFrontend(factory, str(tmp_path), fault_plan=plan,
                          snapshot_budget_s=30.0, clock=clk,
                          frontend_kwargs=dict(queue_depth=32,
                                               decode_steps=1))
    dfe.init_state()
    _submit_all(dfe)
    pumps = 0
    while dfe.pending():
        pumps += 1
        assert pumps < 200, "recovery liveness failure"
        try:
            dfe.pump(params)
        except ProcessKilled:
            before = (dfe.stats["replayed_submits"]
                      + dfe.stats["replayed_rounds"])
            dfe.recover(params)
            n = (dfe.stats["replayed_submits"]
                 + dfe.stats["replayed_rounds"]) - before
            # replay spans ONE clock tick (no journaling inside it), so
            # the re-measured rate is exactly 1s / n records
            assert n > 0
            assert dfe._replay_s_per_record == pytest.approx(1.0 / n)
            assert dfe._records_since_snapshot == 0   # post-recovery base
    assert dfe.stats["recoveries"] == 1
    got_tokens, got_status = _results(dfe.fe.tickets)
    assert got_status == ctrl_status and got_tokens == ctrl_tokens


@pytest.mark.slow
def test_packed_pending_defers_snapshots_and_recovers(tiny_model, tmp_path):
    """step_mode="packed": while an admission's chunked prefill is in
    flight the engine's host mirrors refuse to serialize, so a due
    snapshot is DEFERRED — including the post-recovery base snapshot
    when the kill lands mid-prefill and replay faithfully reconstructs
    the mid-prefill state. The run must still finish identically to an
    uninterrupted packed control."""
    from repro.configs.base import TreeConfig
    from repro.runtime.faults import ProcessKilled
    from repro.runtime.frontend import ServeFrontend
    from repro.runtime.recovery import DurableFrontend
    from repro.runtime.serve import TreeServeEngine

    cfg, model, params = tiny_model

    def factory():
        return TreeServeEngine(model, cfg, TreeConfig(
            n_nodes=6, depth=2, slots=4, node_capacity=16,
            decode_capacity=8, temperature=0.0, cache_dtype="bfloat16",
            ctx_store="paged", page_size=8, num_pages=8,
            step_mode="packed", prefill_chunk=5, suffix_prefill=True))

    fe = ServeFrontend(factory(), queue_depth=32, decode_steps=1)
    st = fe.init_state()
    _submit_all(fe)
    fe.drain(params, st)
    ctrl_tokens, ctrl_status = _results(fe.tickets)

    plan = FaultPlan([FaultEvent(2, FaultKind.KILL_PROCESS)])
    dfe = DurableFrontend(factory, str(tmp_path), fault_plan=plan,
                          snapshot_every=1,
                          frontend_kwargs=dict(queue_depth=32,
                                               decode_steps=1))
    dfe.init_state()
    _submit_all(dfe)
    pumps = 0
    while dfe.pending():
        pumps += 1
        assert pumps < 200, "recovery liveness failure"
        try:
            dfe.pump(params)
        except ProcessKilled:
            dfe.recover(params)
            # replay landed back in the mid-prefill state: the base
            # snapshot was deferred, journaling continues in the
            # replayed epoch
            assert dfe.fe.engine._pending
            assert dfe.journal is not None
    assert dfe.stats["recoveries"] == 1
    assert dfe.stats["deferred_snapshots"] > 0
    got_tokens, got_status = _results(dfe.fe.tickets)
    assert got_status == ctrl_status and got_tokens == ctrl_tokens
    dfe.fe.engine.host_state()    # quiescent again once drained


def _submit_shared(fe_like):
    pfx = [jnp.asarray(SYS_TOKS), jnp.asarray(ALT_TOKS)]
    for i, r in enumerate(SHARED_REQ_TOKS):
        fe_like.submit([pfx[i % 2], jnp.asarray(r)], n_samples=1,
                       max_new_tokens=5)


@pytest.mark.slow
def test_sharing_policy_admit_order_replays_divergence_free(tiny_model,
                                                            tmp_path):
    """Regression: with ``policy="sharing"`` the admission ORDER is a
    scheduling decision, not a stable function of the ticket table — it
    depends on the trie the policy saw at that round. The frontend
    journals the chosen order (``admit_order`` event) before admitting,
    so replay both re-derives it and CROSS-CHECKS it; a killed-and-
    recovered sharing run must finish bit-identical to its control."""
    from repro.runtime.faults import ProcessKilled
    from repro.runtime.frontend import ServeFrontend
    from repro.runtime.recovery import DurableFrontend

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    fe = ServeFrontend(factory(), queue_depth=32, decode_steps=1,
                       policy="sharing")
    st = fe.init_state()
    _submit_shared(fe)
    fe.drain(params, st)
    ctrl_tokens, ctrl_status = _results(fe.tickets)
    assert all(s == "completed" for s in ctrl_status.values())

    plan = FaultPlan([FaultEvent(2, FaultKind.KILL_PROCESS),
                      FaultEvent(4, FaultKind.KILL_PROCESS)])
    dfe = DurableFrontend(factory, str(tmp_path), fault_plan=plan,
                          snapshot_every=2, keep_last_k=100,
                          frontend_kwargs=dict(queue_depth=32,
                                               decode_steps=1,
                                               policy="sharing"))
    dfe.init_state()
    _submit_shared(dfe)
    pumps = 0
    while dfe.pending():
        pumps += 1
        assert pumps < 200, "recovery liveness failure"
        try:
            dfe.pump(params)
        except ProcessKilled:
            dfe.recover(params)
    assert dfe.stats["recoveries"] == 2
    assert dfe.stats["replayed_rounds"] > 0   # the cross-check really ran
    got_tokens, got_status = _results(dfe.fe.tickets)
    assert got_status == ctrl_status
    assert got_tokens == ctrl_tokens

    # the journal carries the ORDER, and the order is non-trivial: the
    # sharing policy pulls same-prefix siblings ahead of earlier tids,
    # so at least one journaled admit_order is NOT in fifo (tid) order
    orders = []
    for name in sorted(os.listdir(dfe.journal_dir)):
        recs, _ = Journal.read(os.path.join(dfe.journal_dir, name))
        for rec in recs:
            if rec.get("ev") == "round":
                orders += [o["tids"] for o in rec["obs"]
                           if o.get("ev") == "admit_order"]
    assert orders, "no admit_order events journaled"
    assert any(o != sorted(o) for o in orders), orders


@pytest.mark.slow
def test_tampered_admit_order_is_a_replay_divergence(tiny_model, tmp_path):
    """Anti-regression for the cross-check itself: swap two tids inside a
    journaled ``admit_order`` (same SET, different order, valid CRCs) and
    recovery must refuse with ``ReplayDivergence`` rather than silently
    re-admitting in whatever order the replayed policy derives."""
    from repro.runtime.faults import ProcessKilled
    from repro.runtime.recovery import DurableFrontend, ReplayDivergence

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    plan = FaultPlan([FaultEvent(3, FaultKind.KILL_PROCESS)])
    dfe = DurableFrontend(factory, str(tmp_path), fault_plan=plan,
                          snapshot_every=100, keep_last_k=100,
                          frontend_kwargs=dict(queue_depth=32,
                                               decode_steps=1,
                                               policy="sharing"))
    dfe.init_state()
    _submit_shared(dfe)
    with pytest.raises(ProcessKilled):
        while dfe.pending():
            dfe.pump(params)

    ep = os.path.join(dfe.journal_dir, "journal_000000000.log")
    recs, clean = Journal.read(ep)
    assert clean
    swapped = False
    for rec in recs:
        if rec["ev"] != "round":
            continue
        for o in rec["obs"]:
            if o.get("ev") == "admit_order" and len(o["tids"]) >= 2:
                o["tids"][0], o["tids"][1] = o["tids"][1], o["tids"][0]
                swapped = True
                break
        if swapped:
            break
    assert swapped, "no multi-ticket admit_order to tamper with"
    os.remove(ep)
    j = Journal(ep)
    for rec in recs:
        j.append(rec)
    j.close()
    with pytest.raises(ReplayDivergence, match="admit_order"):
        dfe.recover(params)


@pytest.mark.slow
def test_nan_sentinel_quarantines_only_poisoned_request(tiny_model):
    """Poison ONE request's private trie node with NaNs: its decode
    output goes non-finite, the sentinel flags the slot, the frontend
    cancels it through the ordinary retirement path and rejects it with
    the typed ``kv_corruption`` reason — its neighbour, sharing the
    prefix node, completes untouched."""
    from repro.runtime.frontend import (
        COMPLETED, REASON_KV_CORRUPTION, REJECTED, ServeFrontend)

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "dense", "bfloat16")
    fe = ServeFrontend(factory(), queue_depth=32, decode_steps=1)
    state = fe.init_state()
    sys_ = jnp.asarray(SYS_TOKS)
    ta = fe.submit([sys_, jnp.asarray(REQ_TOKS[0])], max_new_tokens=5)
    tb = fe.submit([sys_, jnp.asarray(REQ_TOKS[1])], max_new_tokens=5)
    state = fe.pump(params, state)
    assert fe.ticket(ta).status == "running"
    # the victim's PRIVATE suffix node (refcount 1; the shared root
    # stays healthy so the blast radius must stay at one request)
    nid = fe.engine.requests[fe.ticket(ta).handle]["path"][-1]
    cache = state.cache
    state = dataclasses.replace(
        state, cache=dataclasses.replace(
            cache, k_ctx=cache.k_ctx.at[:, nid].set(jnp.nan)))
    state = fe.drain(params, state)
    a, b = fe.ticket(ta), fe.ticket(tb)
    assert (a.status, a.reason) == (REJECTED, REASON_KV_CORRUPTION)
    assert b.status == COMPLETED
    assert len(b.tokens[0]) == 5
    assert fe.counters.get("kv_quarantines") == 1


@pytest.mark.slow
def test_audit_verify_checksums_catches_kv_flip(tiny_model):
    """``audit_state(verify_checksums=True)`` recomputes every live
    segment's fingerprint: a single flipped byte in live context raises
    ``KVCorruption``; pristine state passes."""
    from repro.runtime.frontend import ServeFrontend

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "dense", "bfloat16")
    fe = ServeFrontend(factory(), decode_steps=1)
    state = fe.init_state()
    fe.submit([jnp.asarray(SYS_TOKS), jnp.asarray(REQ_TOKS[0])],
              max_new_tokens=5)
    state = fe.pump(params, state)
    fe.engine.audit_state(state, verify_checksums=True)   # pristine: ok
    nid = fe.engine.requests[0]["path"][0]
    bad = dataclasses.replace(
        state, cache=dataclasses.replace(
            state.cache,
            k_ctx=state.cache.k_ctx.at[0, nid, 0, 0].set(1e9)))
    with pytest.raises(KVCorruption, match="checksum"):
        fe.engine.audit_state(bad, verify_checksums=True)


@pytest.mark.slow
def test_stale_heartbeat_triggers_supervised_restart(tiny_model, tmp_path):
    """A wedged pump loop (simulated by hand-aging the heartbeat file)
    must surface as ``StaleHeartbeat``; ``run_supervised`` recovers from
    the latest snapshot and the workload still finishes with exact
    budgets."""
    from repro.runtime.fault_tolerance import StaleHeartbeat
    from repro.runtime.frontend import COMPLETED
    from repro.runtime.recovery import DurableFrontend

    cfg, model, params = tiny_model
    factory = _factory(cfg, model, "tree", "paged", "bfloat16")
    hb_path = str(tmp_path / "hb")
    dfe = DurableFrontend(factory, str(tmp_path / "state"),
                          snapshot_every=2, heartbeat_path=hb_path,
                          stale_after_s=60.0,
                          frontend_kwargs=dict(decode_steps=1))
    dfe.init_state()
    _submit_all(dfe)
    wedged = {"armed": True}

    def work(d, p):
        pumps = 0
        while d.pending():
            pumps += 1
            assert pumps < 200
            if wedged["armed"] and d.fe.round == 3:
                # simulate a hang: the beat on disk is suddenly ancient
                wedged["armed"] = False
                open(hb_path, "w").write(f"3 {time.time() - 3600}\n")
            d.pump(p)
        return d

    with pytest.raises(StaleHeartbeat):
        # un-supervised, the stale beat is fatal …
        work(dfe, params)
    # … supervised, it recovers from checkpoint and finishes
    dfe.run_supervised(params, work, max_restarts=3)
    for t in dfe.fe.tickets:
        assert t.status == COMPLETED
        assert all(len(tok) == 5 for tok in t.tokens)
    assert dfe.stats["recoveries"] >= 1
