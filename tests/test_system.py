"""End-to-end behaviour tests for the paper's system: the full
prefill -> fork -> bifurcated-decode -> rerank pipeline, and the dry-run /
sharding path on a small forced-multi-device mesh (subprocess, so the main
test process keeps its single CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # CI runs the slow tier in its own step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def test_end_to_end_single_context_batch_sampling():
    from repro.configs import ServeConfig, get_config, reduced_config
    from repro.core.policy import BifurcationPolicy
    from repro.models import get_model
    from repro.runtime.serve import ServeEngine, rank_by_mean_logprob

    cfg = reduced_config(get_config("h2o-danube-1.8b"))  # SWA arch
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctx = jnp.asarray(np.random.RandomState(5).randint(
        0, cfg.vocab_size, (1, 40)))
    outs = {}
    for bif in (True, False):
        scfg = ServeConfig(batch=5, decode_capacity=16, bifurcated=bif)
        eng = ServeEngine(model, cfg, scfg,
                          policy=BifurcationPolicy(enabled=bif,
                                                   min_io_saving_bytes=0))
        outs[bif] = eng.generate(params, ctx, n_steps=10,
                                 key=jax.random.PRNGKey(1))
    agree = float(np.mean(np.asarray(outs[True].tokens)
                          == np.asarray(outs[False].tokens)))
    assert agree >= 0.85, agree  # bf16 split-sum near-tie tolerance
    top = rank_by_mean_logprob(outs[True], top_k=3)
    assert 1 <= len(top) <= 3


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_sharded_serve_step_compiles_on_8_device_mesh():
    """Small-mesh version of the dry-run: lower+compile the sharded
    serve_step for a reduced arch on a (2, 4) data x model mesh and assert
    the SPMD module contains collectives and fits."""
    out = _run_subprocess("""
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.launch import specs as S, steps as ST
        from repro.launch.hlo_cost import analyze

        cfg = reduced_config(get_config("internlm2-1.8b"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            model, step, rules = ST.build_serve(cfg, mesh, impl="flash")
            params = S.param_specs(model)
            io = S.decode_cache_specs(cfg, model, 64, 8, bifurcated=True)
            psh = ST.to_named(mesh, ST.param_pspec_tree(params, rules))
            csh = ST.to_named(mesh, ST.cache_pspec_tree(mesh, io["cache"]))
            tsh = ST.to_named(mesh, ST.batch_pspec_tree(mesh, {"tokens": io["tokens"]}))["tokens"]
            ksh = ST.to_named(mesh, jax.sharding.PartitionSpec(None))
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            compiled = jax.jit(step, in_shardings=(psh, csh, tsh, ksh),
                               donate_argnums=(1,)).lower(
                params, io["cache"], io["tokens"], key).compile()
        cost = analyze(compiled.as_text())
        mem = compiled.memory_analysis()
        print(json.dumps({
            "flops": cost["flops"],
            "coll": cost["collective_bytes"],
            "arg_bytes": int(mem.argument_size_in_bytes),
        }))
    """)
    assert out["flops"] > 0
    assert out["arg_bytes"] > 0


def test_sharded_serve_step_compiles_with_int8_cache():
    """The quantized-context cache (int8 values + f32 scale leaves, both
    sequence-sharded over "model") lowers and compiles through the same
    sharded serve_step; the cache argument footprint lands well under the
    bf16 cache's."""
    out = _run_subprocess("""
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.launch import specs as S, steps as ST

        cfg = reduced_config(get_config("internlm2-1.8b"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sizes = {}
        with mesh:
            model, step, rules = ST.build_serve(cfg, mesh, impl="flash")
            params = S.param_specs(model)
            for quant in ("none", "int8"):
                io = S.decode_cache_specs(cfg, model, 64, 8, bifurcated=True,
                                          ctx_quant=quant)
                psh = ST.to_named(mesh, ST.param_pspec_tree(params, rules))
                csh = ST.to_named(mesh, ST.cache_pspec_tree(mesh, io["cache"]))
                tsh = ST.to_named(mesh, ST.batch_pspec_tree(
                    mesh, {"tokens": io["tokens"]}))["tokens"]
                ksh = ST.to_named(mesh, jax.sharding.PartitionSpec(None))
                key = jax.ShapeDtypeStruct((2,), jnp.uint32)
                compiled = jax.jit(step, in_shardings=(psh, csh, tsh, ksh),
                                   donate_argnums=(1,)).lower(
                    params, io["cache"], io["tokens"], key).compile()
                cache_bytes = sum(
                    l.size * l.dtype.itemsize
                    for l in jax.tree.leaves(io["cache"]))
                sizes[quant] = cache_bytes
        print(json.dumps(sizes))
    """)
    # ctx arm halves; decode arm unchanged — total strictly smaller
    assert out["int8"] < out["none"]


def test_sharded_train_step_runs_on_8_device_mesh():
    """Actually EXECUTE (not just compile) one sharded train step on 8
    forced host devices — proves shardings are not just compile-coherent."""
    out = _run_subprocess("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import TrainConfig, get_config, reduced_config
        from repro.launch import steps as ST
        from repro.distributed.sharding import named_sharding_tree
        from repro.data import SyntheticLMDataset

        cfg = reduced_config(get_config("internlm2-1.8b"))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        tcfg = TrainConfig(global_batch=8, seq_len=32, remat="none",
                           warmup_steps=2, total_steps=10)
        with mesh:
            model, step, rules = ST.build_train(cfg, mesh, tcfg)
            params = model.init(jax.random.PRNGKey(0))
            from repro.optim import adamw_init
            state = {"params": params, "opt_state": adamw_init(params)}
            psh = named_sharding_tree(state, mesh, rules)
            state = jax.device_put(state, psh)
            data = SyntheticLMDataset(cfg.vocab_size, 32)
            batch = {k: jnp.asarray(v) for k, v in data.batch(0, 8).items()}
            jstep = jax.jit(step, donate_argnums=(0,))
            state, m1 = jstep(state, batch)
            batch2 = {k: jnp.asarray(v) for k, v in data.batch(1, 8).items()}
            state, m2 = jstep(state, batch2)
        print(json.dumps({"loss0": float(m1["loss"]), "loss1": float(m2["loss"])}))
    """)
    assert np.isfinite(out["loss0"]) and np.isfinite(out["loss1"])
