"""Fault-tolerant serving frontend (runtime/frontend.py) + fault injection
(runtime/faults.py).

Fast (host-only) tier: FaultPlan determinism/serialization surface.

Slow tier (real model + engines, CPU):
  * the admission ladder end-to-end: admit -> queue/backoff -> preempt ->
    typed reject, with every ticket terminal in an allowed end state;
  * preemption policy: lowest effective priority evicted first, victim
    re-queued and finished (preempted-then-completed), priority aging
    terminates preemption cycles;
  * deadlines (queued AND running) reject with ``deadline_exceeded``;
  * the stuck-decode watchdog breaking a DELAYED_RETIREMENT hold;
  * the BLAST-RADIUS differential contract (the acceptance bar): replay
    the same workload with and without a FaultPlan — requests untouched
    by any fault must produce bit-identical greedy tokens, on the trie
    AND the flat forest, and ``PageAllocator.audit`` passes at every
    round of both runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ForestConfig, TreeConfig, get_config, reduced_config
from repro.models import get_model
from repro.runtime.faults import FaultEvent, FaultKind, FaultPlan
from repro.runtime.frontend import (
    COMPLETED,
    QUEUED,
    REASON_DEADLINE,
    REASON_INFEASIBLE,
    REASON_QUEUE_FULL,
    REJECTED,
    RUNNING,
    ServeFrontend,
)
from repro.runtime.serve import ForestServeEngine, TreeServeEngine


# ---------------------------------------------------------------------------
# Fast: fault plans are pure functions of their seed
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_sorted():
    a = FaultPlan.random(seed=3, rounds=50, rate=0.5)
    b = FaultPlan.random(seed=3, rounds=50, rate=0.5)
    assert a.events == b.events and len(a) > 0
    assert all(e.kind in FaultKind.ALL for e in a.events)
    assert [e.round for e in a.events] == sorted(e.round for e in a.events)
    assert FaultPlan.random(seed=4, rounds=50, rate=0.5).events != a.events
    # victim choice consumes a seeded stream: same plan -> same choices
    picks = [FaultPlan(seed=9).choose(list(range(10))) for _ in range(5)]
    assert picks == [FaultPlan(seed=9).choose(list(range(10)))
                     for _ in range(5)]
    assert FaultPlan(seed=9).choose([]) is None
    assert sum(FaultPlan.random(0, 40, rate=1.0).counts().values()) == 40


def test_fault_plan_at_and_explicit_events():
    ev = [FaultEvent(5, FaultKind.POOL_EXHAUST, arg=3, hold=2),
          FaultEvent(2, FaultKind.DOUBLE_RELEASE)]
    plan = FaultPlan(ev, seed=0)
    assert [e.round for e in plan.events] == [2, 5]
    assert plan.at(5) == [ev[0]] and plan.at(3) == []
    assert "pool_exhaust" in repr(plan)


# ---------------------------------------------------------------------------
# Slow: real engines
# ---------------------------------------------------------------------------

CFG = reduced_config(get_config("internlm2-1.8b"))
RNG = np.random.RandomState(0)
SYS = jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 12)))
REQS = [jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 7)))
        for _ in range(6)]


@pytest.fixture(scope="module")
def model_params():
    model = get_model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


def _tree_engine(model, **kw):
    tcfg = TreeConfig(**{**dict(n_nodes=4, depth=2, slots=4,
                                node_capacity=16, decode_capacity=8,
                                temperature=0.0, ctx_store="paged",
                                page_size=8, num_pages=5), **kw})
    return TreeServeEngine(model, CFG, tcfg)


def _forest_engine(model, **kw):
    fcfg = ForestConfig(**{**dict(n_groups=3, slots=4, ctx_capacity=24,
                                  decode_capacity=8, temperature=0.0,
                                  ctx_store="paged", page_size=8,
                                  num_pages=5), **kw})
    return ForestServeEngine(model, CFG, fcfg)


@pytest.mark.slow
def test_submit_never_raises_typed_rejections(model_params):
    """Infeasible requests and queue overflow reject at submit with a
    typed reason — no exception ever reaches the caller."""
    model, params = model_params
    fe = ServeFrontend(_tree_engine(model), queue_depth=2)
    # n_samples > slots: permanently infeasible
    t0 = fe.ticket(fe.submit([SYS], n_samples=9))
    assert (t0.status, t0.reason) == (REJECTED, REASON_INFEASIBLE)
    # decode budget > decode capacity
    t1 = fe.ticket(fe.submit([SYS], max_new_tokens=64))
    assert (t1.status, t1.reason) == (REJECTED, REASON_INFEASIBLE)
    # node longer than node_capacity
    long = jnp.zeros((1, 17), jnp.int32)
    t2 = fe.ticket(fe.submit([long]))
    assert (t2.status, t2.reason) == (REJECTED, REASON_INFEASIBLE)
    # queue overflow past queue_depth (nothing pumped yet, so every
    # accepted submit sits QUEUED)
    tids = [fe.submit([SYS, REQS[i % len(REQS)]]) for i in range(4)]
    statuses = [fe.ticket(t).status for t in tids]
    assert statuses == [QUEUED, QUEUED, REJECTED, REJECTED]
    assert fe.ticket(tids[-1]).reason == REASON_QUEUE_FULL
    del params   # submit-side ladder only — nothing ever decodes


@pytest.mark.slow
def test_drain_oversubscribed_all_complete_exact_budgets(model_params):
    """More work than the engine can hold at once: the queue absorbs it,
    everything completes, every completion has EXACTLY max_new_tokens
    greedy tokens, audits pass every round."""
    model, params = model_params
    fe = ServeFrontend(_tree_engine(model))
    state = fe.init_state()
    for i in range(6):
        fe.submit([SYS, REQS[i]], n_samples=1 + (i % 2), max_new_tokens=5)
    fe.drain(params, state, max_rounds=80)
    m = fe.metrics()
    assert m["by_status"] == {COMPLETED: 6}
    for t in fe.tickets:
        assert all(len(tok) == 5 for tok in t.tokens)
        assert all(len(lp) == 5 for lp in t.logprobs)
    assert m["counters"]["audits_passed"] == m["rounds"]
    assert m["counters"].get("backoffs", 0) > 0   # pressure was real


@pytest.mark.slow
def test_preemption_priority_and_requeue(model_params):
    """Under pool pressure a high-priority arrival evicts the lowest
    effective priority victim; the victim re-queues and ends
    preempted-then-completed with the same greedy tokens."""
    model, params = model_params
    # pool sized so two 2-node requests cannot coexist
    fe = ServeFrontend(_tree_engine(model, num_pages=4),
                       preempt_after=1, backoff_base=1)
    state = fe.init_state()
    lo = fe.submit([SYS, REQS[0]], priority=0, max_new_tokens=6)
    state = fe.pump(params, state)
    assert fe.ticket(lo).status == RUNNING
    hi = fe.submit([jnp.asarray(RNG.randint(0, CFG.vocab_size, (1, 12))),
                    REQS[1]], priority=2, max_new_tokens=6)
    state = fe.drain(params, state, max_rounds=60)
    tlo, thi = fe.ticket(lo), fe.ticket(hi)
    assert thi.status == COMPLETED and thi.preemptions == 0
    assert tlo.status == COMPLETED and tlo.preemptions >= 1
    assert fe.counters.get("preemptions_pressure", 0) >= 1
    assert all(len(tok) == 6 for tok in tlo.tokens)
    # baseline: same request alone, no pressure -> identical greedy tokens
    fe2 = ServeFrontend(_tree_engine(model, num_pages=4))
    s2 = fe2.init_state()
    ref = fe2.submit([SYS, REQS[0]], max_new_tokens=6)
    fe2.drain(params, s2, max_rounds=30)
    for a, b in zip(tlo.tokens, fe2.ticket(ref).tokens):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_deadlines_reject_queued_and_running(model_params):
    model, params = model_params
    fe = ServeFrontend(_tree_engine(model, num_pages=3))
    state = fe.init_state()
    # hog the pool so the second request starves in the queue — it must
    # NOT share the hog's prefix, or the trie would admit it for free
    hog = fe.submit([SYS], n_samples=1, max_new_tokens=8)
    starved = fe.submit([REQS[4], REQS[0]], deadline_rounds=2,
                        max_new_tokens=8)
    running = fe.submit([SYS], n_samples=1, deadline_rounds=1,
                        max_new_tokens=8)
    fe.drain(params, state, max_rounds=60)
    assert fe.ticket(hog).status == COMPLETED
    t = fe.ticket(starved)
    assert (t.status, t.reason) == (REJECTED, REASON_DEADLINE)
    t = fe.ticket(running)   # admitted round 1, deadline hits mid-decode
    assert (t.status, t.reason) == (REJECTED, REASON_DEADLINE)
    assert fe.counters.get("deadline_cancels", 0) >= 1


@pytest.mark.slow
def test_watchdog_breaks_delayed_retirement_hold(model_params):
    """A DELAYED_RETIREMENT fault pins finished requests; the watchdog
    must break the hold and let the pipeline drain."""
    model, params = model_params
    # fire at round 1 so the hold lands before the (fast) requests retire
    plan = FaultPlan([FaultEvent(1, FaultKind.DELAYED_RETIREMENT,
                                 hold=50)])
    fe = ServeFrontend(_tree_engine(model), fault_plan=plan,
                       stall_rounds=3)
    state = fe.init_state()
    for i in range(3):
        fe.submit([SYS, REQS[i]], max_new_tokens=4)
    fe.drain(params, state, max_rounds=60)
    assert all(t.status == COMPLETED for t in fe.tickets)
    assert fe.counters.get("retirement_suppressed", 0) > 0
    assert fe.counters.get("watchdog_fires", 0) >= 1


def _replay(model, params, make_engine, reqs, plan, max_new_tokens=5):
    fe = ServeFrontend(make_engine(model), fault_plan=plan,
                       stall_rounds=4)
    state = fe.init_state()
    for segs, k, pr in reqs:
        fe.submit(segs, n_samples=k, priority=pr,
                  max_new_tokens=max_new_tokens)
    fe.drain(params, state, max_rounds=120)
    return fe


@pytest.mark.slow
@pytest.mark.parametrize("which", ["tree", "forest"])
def test_blast_radius_tokens_bit_identical_under_faults(model_params,
                                                        which):
    """THE acceptance contract: the same workload replayed with a fault
    plan covering all four kinds — requests a fault never touched return
    bit-identical greedy tokens to the fault-free run; fault-touched
    requests still END WELL (preempted-then-completed, identical tokens
    too, since greedy re-runs are deterministic)."""
    model, params = model_params
    make = _tree_engine if which == "tree" else _forest_engine
    if which == "tree":
        reqs = [([SYS, REQS[i]], 1 + (i % 2), i % 2) for i in range(4)]
    else:
        reqs = [([jnp.concatenate([SYS, REQS[i]], axis=1)],
                 1 + (i % 2), i % 2) for i in range(4)]
    plan = FaultPlan([
        FaultEvent(2, FaultKind.POOL_EXHAUST, arg=2, hold=2),
        FaultEvent(3, FaultKind.DOUBLE_RELEASE),
        FaultEvent(4, FaultKind.DELAYED_RETIREMENT, hold=2),
        FaultEvent(5, FaultKind.CANCEL_MID_DECODE),
    ], seed=1)
    base = _replay(model, params, make, reqs, None)
    faulty = _replay(model, params, make, reqs, plan)

    assert all(t.status == COMPLETED for t in base.tickets)
    assert all(t.status == COMPLETED for t in faulty.tickets)
    assert faulty.counters.get("fault_cancel_mid_decode", 0) == 1
    assert faulty.counters.get("double_release_refused", 0) == 1
    touched = [t for t in faulty.tickets if t.fault_touched]
    assert len(touched) == 1 and touched[0].preemptions >= 1
    # audits passed at EVERY round of both runs
    for fe in (base, faulty):
        assert fe.counters["audits_passed"] == fe.metrics()["rounds"]
    # bit-identity — for untouched requests by contract, and (greedy)
    # for the preempted one too
    for b, f in zip(base.tickets, faulty.tickets):
        assert len(b.tokens) == len(f.tokens)
        for x, y in zip(b.tokens, f.tokens):
            np.testing.assert_array_equal(x, y)
