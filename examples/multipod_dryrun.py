"""Multi-pod dry-run demo: lower + compile one cell on the 2x16x16 mesh
(512 placeholder devices) and print its roofline terms.

  PYTHONPATH=src python examples/multipod_dryrun.py [--arch internlm2-1.8b] \
      [--shape decode_32k]

This is a thin wrapper over repro.launch.dryrun (which owns the mandatory
XLA_FLAGS device-count override); see launch/sweep.py for the full 40-cell
matrix.
"""
import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    for flag in ([], ["--multi-pod"]):
        print(f"=== {'multi-pod (2x16x16)' if flag else 'single-pod (16x16)'} ===")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", args.arch, "--shape", args.shape] + flag,
            check=True, env=env, cwd=REPO)


if __name__ == "__main__":
    main()
