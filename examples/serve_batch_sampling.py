"""End-to-end serving driver (the paper's target scenario, §5.2.2/§5.4):
single-context batch sampling with reranking under a latency budget.

  PYTHONPATH=src python examples/serve_batch_sampling.py [--batch 16]

Trains nothing; uses a reduced GQA model, generates n samples from one
shared prompt at several batch sizes, ranks by mean log-probability
(pass@top-k reranking), and reports per-step wall clock — demonstrating the
paper's point that batch size scales at ~flat per-step latency because the
shared-context KV is read once.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ServeConfig, get_config, reduced_config
from repro.models import get_model
from repro.runtime.serve import ServeEngine, rank_by_mean_logprob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache-dtype", default="bfloat16",
                    choices=["bfloat16", "int8"],
                    help="context-arm KV dtype (int8: quantized shared "
                         "prefix, core/quantized.py)")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctx = jnp.asarray(
        np.random.RandomState(1).randint(0, cfg.vocab_size, (1, args.context)))

    print(f"arch={cfg.name} (reduced) context={args.context} steps={args.steps}")
    print(f"{'batch':>6} {'bifurcated':>10} {'ms/step':>8} {'best mean-logp':>15}")
    for batch in (1, 4, 16, 64):
        for bif in (False, True):
            from repro.core.policy import BifurcationPolicy

            scfg = ServeConfig(batch=batch, decode_capacity=args.steps + 8,
                               bifurcated=bif, cache_dtype=args.cache_dtype)
            # demo model is reduced-size: force past the production IO
            # threshold so the comparison exercises the real bifurcated path
            engine = ServeEngine(model, cfg, scfg,
                                 policy=BifurcationPolicy(
                                     enabled=bif, min_io_saving_bytes=0))
            # warmup (compile)
            engine.generate(params, ctx, n_steps=2, batch=batch,
                            key=jax.random.PRNGKey(0))
            t0 = time.perf_counter()
            out = engine.generate(params, ctx, n_steps=args.steps, batch=batch,
                                  key=jax.random.PRNGKey(2))
            jax.block_until_ready(out.tokens)
            ms = (time.perf_counter() - t0) / args.steps * 1e3
            used = engine.should_bifurcate(batch, args.context) and bif
            best = rank_by_mean_logprob(out, top_k=3)
            print(f"{batch:>6} {str(used):>10} {ms:8.2f} "
                  f"{float(out.mean_logprob[best[0]]):15.3f}")


if __name__ == "__main__":
    main()
