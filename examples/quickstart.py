"""Quickstart: bifurcated attention in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

1. Build a small GQA LM (any of the 10 assigned archs, reduced).
2. Prefill ONE shared context once.
3. Sample 8 continuations in parallel — the context KV is stored unbatched
   and read once per step (paper Eq. 3-6), via the BifurcatedCache.
4. Verify against the standard batched-cache path (exact same tokens).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ServeConfig, get_config, reduced_config
from repro.core.policy import BifurcationPolicy
from repro.models import get_model
from repro.runtime.serve import ServeEngine


def main():
    cfg = reduced_config(get_config("internlm2-1.8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    context = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab_size, (1, 64)))
    batch, steps = 8, 12

    results = {}
    for bifurcated in (True, False):
        scfg = ServeConfig(batch=batch, decode_capacity=steps + 4,
                           temperature=0.8, top_p=0.95, bifurcated=bifurcated)
        # this demo model is tiny — force past the production IO threshold
        policy = BifurcationPolicy(enabled=bifurcated, min_io_saving_bytes=0)
        engine = ServeEngine(model, cfg, scfg, policy=policy)
        out = engine.generate(params, context, n_steps=steps,
                              key=jax.random.PRNGKey(7))
        results[bifurcated] = out
        mode = "bifurcated" if bifurcated else "standard  "
        print(f"{mode}: sampled {out.tokens.shape} tokens; "
              f"best mean-logp {float(out.mean_logprob.max()):.3f}")

    agree = float(jnp.mean(
        (results[True].tokens == results[False].tokens).astype(jnp.float32)))
    print(f"token agreement across cache layouts: {agree:.3f} "
          "(fp32-exact per paper App. E.1; bf16 split-sum may flip near-ties)")
    assert agree >= 0.85, agree


if __name__ == "__main__":
    main()
