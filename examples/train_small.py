"""End-to-end training driver with fault tolerance: train a small LM for a
few hundred steps, checkpoint every 50, KILL the loop partway, and resume
from the latest checkpoint — demonstrating checkpoint/restart and
deterministic data replay.

  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil
import tempfile

from repro.configs import TrainConfig, get_config, reduced_config
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.runtime.train_loop import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    tcfg = TrainConfig(global_batch=16, seq_len=args.seq_len,
                       learning_rate=1e-3, warmup_steps=20,
                       total_steps=args.steps, checkpoint_every=50)
    model = get_model(cfg)
    data = SyntheticLMDataset(cfg.vocab_size, args.seq_len, seed=0)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = args.steps // 2
        print(f"phase 1: train to step {half} (simulated failure after)")
        r1 = run_training(model, cfg, tcfg, data, num_steps=half,
                          checkpoint_dir=ckpt_dir)
        print(f"  final loss {r1.losses[-1][1]:.4f}")

        print("phase 2: 'restart' — auto-resume from latest checkpoint")
        r2 = run_training(model, cfg, tcfg, data, num_steps=args.steps,
                          checkpoint_dir=ckpt_dir)
        print(f"  resumed from step {r2.resumed_from}, "
              f"final loss {r2.losses[-1][1]:.4f}")
        assert r2.resumed_from == half
        assert r2.losses[-1][1] < r1.losses[0][1], "loss should improve"
        print("checkpoint/restart OK; loss improved across the failure")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
