"""Docs lint: markdown link check + runnable-quickstart check.

Two passes, both CI-enforced (.github/workflows/ci.yml, "Docs lint"):

  1. LINK CHECK over ``docs/*.md``, ``README.md`` and
     ``benchmarks/README.md``: every relative markdown link target must
     exist on disk (anchors are stripped; http(s)/mailto links are not
     fetched), and every intra-file ``#anchor`` must match a heading of
     the target file (GitHub slug rules, simplified).

  2. DOCTEST-STYLE RUN of every fenced ```python block in ``docs/*.md``:
     blocks execute top-to-bottom in one namespace PER FILE (so a page's
     later snippets may build on earlier ones), with the repo's ``src/``
     on the path. A block fenced as ```python therefore IS the contract
     that the quickstart runs; illustrative non-runnable fragments must
     use ```text / ``` instead. Fails loudly on any exception.

Usage: ``PYTHONPATH=src python tools/docs_lint.py`` from the repo root
(CI sets JAX_PLATFORMS=cpu; kernels inside doc blocks run in interpret
mode there, exactly like the test suite).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [
    ROOT / "README.md", ROOT / "benchmarks" / "README.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug (simplified: lowercase, strip punctuation,
    spaces -> dashes)."""
    h = heading.strip().lstrip("#").strip().lower()
    h = re.sub(r"[`*]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _headings(path: pathlib.Path):
    slugs = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if FENCE_RE.match(line) or line.startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence and line.startswith("#"):
            slugs.add(_slug(line))
    return slugs


def check_links() -> list:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md}: file missing")
            continue
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            dest = md.parent / target if target else md
            if not dest.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
                continue
            if frag and dest.suffix == ".md":
                if _slug("#" + frag) not in _headings(dest):
                    errors.append(f"{md.relative_to(ROOT)}: missing anchor "
                                  f"#{frag} in {target or md.name}")
    return errors


def python_blocks(path: pathlib.Path):
    """Yield (starting line number, source) for each ```python fence."""
    lines = path.read_text().splitlines()
    block, start, lang = None, 0, None
    for i, line in enumerate(lines, 1):
        m = FENCE_RE.match(line)
        if m and block is None:
            lang, start, block = m.group(1), i, []
        elif line.startswith("```") and block is not None:
            if lang == "python":
                yield start, "\n".join(block)
            block, lang = None, None
        elif block is not None:
            block.append(line)


def run_doc_blocks() -> list:
    errors = []
    sys.path.insert(0, str(ROOT / "src"))
    for md in sorted((ROOT / "docs").glob("*.md")):
        ns = {"__name__": f"docs::{md.name}"}
        for lineno, src in python_blocks(md):
            try:
                exec(compile(src, f"{md.name}:{lineno}", "exec"), ns)
            except Exception as e:  # noqa: BLE001 — report, keep linting
                errors.append(
                    f"{md.relative_to(ROOT)} block at line {lineno}: "
                    f"{type(e).__name__}: {e}")
                break   # later blocks in this file may depend on this one
    return errors


def main() -> int:
    errors = check_links()
    n_blocks = sum(
        1 for md in sorted((ROOT / "docs").glob("*.md"))
        for _ in python_blocks(md))
    errors += run_doc_blocks()
    print(f"docs_lint: {len(DOC_FILES)} files link-checked, "
          f"{n_blocks} python blocks executed, {len(errors)} errors")
    for e in errors:
        print(f"  ERROR {e}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
