#!/usr/bin/env python
"""Per-file pytest runner: one subprocess per test module.

Why this exists: the full suite in a SINGLE pytest process segfaults —
dozens of jitted tiny models, three engine families, and Pallas
interpret-mode kernels accumulate enough XLA/CPU client state in one
interpreter to bring it down (observed long before this tool; the crash
moves around with collection order and is not attributable to any one
test). CI has always sidestepped it by splitting the suite across jobs;
this tool is the same sidestep for a laptop: every ``tests/test_*.py``
runs in its OWN interpreter, so state cannot accumulate across modules
and one module's crash cannot take down another's results.

Usage::

    PYTHONPATH=src python tools/run_tests.py               # whole suite
    PYTHONPATH=src python tools/run_tests.py -m "not slow" # fast tier
    PYTHONPATH=src python tools/run_tests.py tests/test_scheduler.py
    PYTHONPATH=src python tools/run_tests.py -- -k sharing -x

Positional args that are paths select test files; everything else
(and anything after ``--``) is passed through to every pytest
invocation verbatim. Exit status is non-zero if ANY module fails.
A module whose subprocess dies on a signal (segfault) is reported as
CRASH — with per-file isolation that points at a real bug in that
module, not at suite-wide state.
"""
import glob
import os
import signal
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)


def _env():
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    files, passthrough, seen_sep = [], [], False
    for a in argv:
        if a == "--" and not seen_sep:
            seen_sep = True
        elif not seen_sep and not a.startswith("-") and a.endswith(".py"):
            files.append(a)
        else:
            passthrough.append(a)
    if not files:
        files = sorted(glob.glob(os.path.join(ROOT, "tests", "test_*.py")))

    results, t0 = [], time.time()
    for path in files:
        name = os.path.relpath(path, ROOT)
        print(f"=== {name} ===", flush=True)
        rc = subprocess.call(
            [sys.executable, "-m", "pytest", "-q", path, *passthrough],
            env=_env(), cwd=ROOT)
        if rc < 0:
            status = f"CRASH ({signal.Signals(-rc).name})"
        elif rc == 5:          # pytest: no tests collected (e.g. -m filter)
            status, rc = "no tests", 0
        else:
            status = "ok" if rc == 0 else f"FAIL (rc={rc})"
        results.append((name, rc, status))

    print(f"\n{'-' * 60}")
    for name, _, status in results:
        print(f"{name:<44} {status}")
    bad = [n for n, rc, _ in results if rc != 0]
    print(f"{'-' * 60}\n{len(results) - len(bad)}/{len(results)} modules "
          f"passed in {time.time() - t0:.0f}s")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
